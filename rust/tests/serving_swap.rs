//! Integration tests of zero-downtime hot swap: concurrent submitters
//! across a `swap_model` must see logits bit-identical to the version
//! their request was admitted under (pool widths {1, 2, 4, 8}), with
//! zero dropped requests and no mixed-epoch batches; rollback restores
//! the previous version mid-traffic; superseded backends are reclaimed
//! (their last `Arc` dropped) once their admitted traffic drains.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::sync::{Arc, Barrier};
use std::time::Duration;

use admm_nn::backend::native::NativeBackend;
use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
use admm_nn::backend::TrainState;
use admm_nn::data::{self, Dataset, Split};
use admm_nn::serving::{
    EngineConfig, InferBackend, InferRequest, ModelRegistry, ServingEngine,
    ServingError,
};
use admm_nn::util::ThreadPool;

/// Package a proxy model without training (structure is what matters);
/// different seeds give different weights, so v1 and v2 logits differ.
fn packaged(name: &str, keep: f64, seed: u64) -> (NativeBackend, SparseInfer) {
    let nb = NativeBackend::open_with_batches(name, 8, 8).expect("backend");
    let mut st = TrainState::init(nb.entry(), seed);
    let model = prune_quantize_package(nb.entry(), name, &mut st, keep, 4, 8);
    let sp = SparseInfer::new(&model, nb.entry()).expect("sparse form");
    (nb, sp)
}

/// Deterministic version-tagged backend for scheduler-path tests:
/// "logits" are the input scaled by the version (exact in f32 for the
/// versions used here), after an optional delay to keep queues full.
struct VersionedEcho {
    version: f32,
    dim: usize,
    delay: Duration,
}

impl VersionedEcho {
    fn arc(version: f32, delay_ms: u64) -> Arc<dyn InferBackend> {
        Arc::new(VersionedEcho {
            version,
            dim: 4,
            delay: Duration::from_millis(delay_ms),
        })
    }
}

impl InferBackend for VersionedEcho {
    fn name(&self) -> &str {
        "versioned-echo"
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.dim
    }

    fn infer_batch(
        &self,
        _pool: &ThreadPool,
        x: &[f32],
        _bsz: usize,
    ) -> admm_nn::Result<Vec<f32>> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        Ok(x.iter().map(|v| v * self.version).collect())
    }
}

fn scaled(x: &[f32], version: f32) -> Vec<f32> {
    x.iter().map(|v| v * version).collect()
}

/// Poll a model's counters until `pred` holds (the retirement bump runs
/// on the dispatch thread after results are published, so observers may
/// race it by a few microseconds).
fn wait_for_stats(
    engine: &ServingEngine,
    model: &str,
    what: &str,
    pred: impl Fn(&admm_nn::metrics::ServingCounters) -> bool,
) -> admm_nn::metrics::ServingCounters {
    for _ in 0..2000 {
        let s = engine.stats(model).expect("model registered");
        if pred(&s) {
            return s;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("stats never satisfied: {what}: {:?}", engine.stats(model));
}

/// The acceptance gate: N submitter threads queue a wave of requests,
/// the main thread hot-swaps the model while that wave is still in
/// flight, then the threads push a second wave. Every pre-swap request
/// must return logits bit-identical to a serial v1 reference, every
/// post-swap request bit-identical to v2 — at pool widths {1, 2, 4, 8},
/// with zero drops and exactly one retired epoch once traffic drains.
#[test]
fn hot_swap_under_concurrent_load_is_epoch_pinned_and_lossless() {
    const THREADS: usize = 4;
    const HALF: usize = 6;

    let (nb, sp1) = packaged("mlp", 0.15, 21);
    let (_, sp2) = packaged("mlp", 0.10, 99);
    let ds = data::for_input_shape(&nb.entry().input_shape);
    let dim = sp1.input_dim();
    let pool_x = ds.batch(Split::Test, 0, 48).x;
    let row = |t: usize, i: usize| -> Vec<f32> {
        let r = (t * 2 * HALF + i) % 48;
        pool_x[r * dim..(r + 1) * dim].to_vec()
    };

    // serial references on a width-1 pool, for both versions
    let serial = ThreadPool::new(1);
    let ref_of = |sp: &SparseInfer, t: usize, i: usize| -> Vec<f32> {
        sp.infer_with(&serial, &row(t, i), 1).expect("serial reference")
    };

    for width in [1usize, 2, 4, 8] {
        let mut reg = ModelRegistry::new();
        reg.register_versioned(
            "mlp".into(),
            Arc::new(packaged("mlp", 0.15, 21).1),
            Some(1),
        )
        .unwrap();
        let engine = ServingEngine::new(reg, EngineConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            pool: Some(Arc::new(ThreadPool::new(width))),
            ..EngineConfig::default()
        })
        .unwrap();
        assert_eq!(engine.epoch(), 0);

        let queued = Barrier::new(THREADS + 1);
        let swapped = Barrier::new(THREADS + 1);
        let results: Vec<(Vec<Vec<f32>>, Vec<Vec<f32>>)> =
            std::thread::scope(|s| {
                let handles: Vec<_> = (0..THREADS)
                    .map(|t| {
                        let engine = &engine;
                        let queued = &queued;
                        let swapped = &swapped;
                        let row = &row;
                        s.spawn(move || {
                            // wave 1: queued (not necessarily dispatched)
                            // before the swap — admission pins epoch 0
                            let w1: Vec<_> = (0..HALF)
                                .map(|i| {
                                    engine
                                        .submit(InferRequest::new(
                                            "mlp",
                                            row(t, i),
                                        ))
                                        .expect("wave-1 submit")
                                })
                                .collect();
                            queued.wait();
                            swapped.wait();
                            // wave 2: admitted strictly after the swap
                            let w2: Vec<_> = (HALF..2 * HALF)
                                .map(|i| {
                                    engine
                                        .submit(InferRequest::new(
                                            "mlp",
                                            row(t, i),
                                        ))
                                        .expect("wave-2 submit")
                                })
                                .collect();
                            let r1: Vec<Vec<f32>> = w1
                                .into_iter()
                                .map(|tk| engine.wait(tk).expect("wave-1 wait"))
                                .collect();
                            let r2: Vec<Vec<f32>> = w2
                                .into_iter()
                                .map(|tk| engine.wait(tk).expect("wave-2 wait"))
                                .collect();
                            (r1, r2)
                        })
                    })
                    .collect();

                queued.wait();
                let epoch = engine
                    .swap_model(
                        "mlp",
                        Arc::new(packaged("mlp", 0.10, 99).1),
                        Some(2),
                    )
                    .expect("swap under load");
                assert_eq!(epoch, 1, "width {width}");
                swapped.wait();

                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

        for (t, (r1, r2)) in results.iter().enumerate() {
            for (i, got) in r1.iter().enumerate() {
                assert_eq!(
                    got,
                    &ref_of(&sp1, t, i),
                    "width {width}: thread {t} pre-swap request {i} \
                     drifted from its admitted version"
                );
            }
            for (i, got) in r2.iter().enumerate() {
                assert_eq!(
                    got,
                    &ref_of(&sp2, t, HALF + i),
                    "width {width}: thread {t} post-swap request {i} \
                     not served by the new version"
                );
            }
        }

        // zero drops, one swap, and the superseded epoch fully retired
        // once its admitted traffic drained
        let want = (THREADS * 2 * HALF) as u64;
        let s = wait_for_stats(&engine, "mlp", "epoch retirement", |s| {
            s.epochs_retired == 1
        });
        assert_eq!(s.submitted, want, "width {width}");
        assert_eq!(s.completed, want, "width {width}: dropped requests");
        assert_eq!(s.failed + s.expired, 0, "width {width}");
        assert_eq!((s.swaps, s.rollbacks), (1, 0), "width {width}");
    }
}

#[test]
fn requests_admitted_before_swap_finish_on_their_admitted_version() {
    let mut reg = ModelRegistry::new();
    reg.register_versioned("echo".into(), VersionedEcho::arc(1.0, 10), Some(1))
        .unwrap();
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 2,
        max_wait: Duration::ZERO,
        queue_cap: 64,
        ..EngineConfig::default()
    })
    .unwrap();

    let x_of = |i: usize| vec![i as f32 + 1.0; 4];
    let pre: Vec<_> = (0..8)
        .map(|i| engine.submit(InferRequest::new("echo", x_of(i))).unwrap())
        .collect();
    let epoch = engine
        .swap_model("echo", VersionedEcho::arc(2.0, 0), Some(2))
        .unwrap();
    assert_eq!(epoch, 1);
    let post: Vec<_> = (8..16)
        .map(|i| engine.submit(InferRequest::new("echo", x_of(i))).unwrap())
        .collect();

    // pre-swap requests (mostly still queued during the swap) all run
    // on v1; post-swap requests all run on v2 — a batch that mixed
    // epochs would break one side or the other bit-exactly
    for (i, t) in pre.into_iter().enumerate() {
        assert_eq!(engine.wait(t).unwrap(), scaled(&x_of(i), 1.0), "pre {i}");
    }
    for (i, t) in post.into_iter().enumerate() {
        let i = i + 8;
        assert_eq!(engine.wait(t).unwrap(), scaled(&x_of(i), 2.0), "post {i}");
    }

    // lineage: v2 live, v1 kept as the rollback target
    let lineage = engine.versions("echo").unwrap();
    assert_eq!(lineage.len(), 2);
    assert_eq!(
        (lineage[0].epoch, lineage[0].store_version, lineage[0].live),
        (1, Some(2), true)
    );
    assert_eq!(
        (lineage[1].epoch, lineage[1].store_version, lineage[1].live),
        (0, Some(1), false)
    );

    let s = wait_for_stats(&engine, "echo", "drain", |s| s.epochs_retired == 1);
    assert_eq!((s.submitted, s.completed), (16, 16));
    assert_eq!(s.failed + s.expired, 0);
}

#[test]
fn rollback_mid_traffic_restores_the_previous_version() {
    let mut reg = ModelRegistry::new();
    reg.register_versioned("echo".into(), VersionedEcho::arc(1.0, 0), Some(1))
        .unwrap();
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 1,
        max_wait: Duration::ZERO,
        queue_cap: 64,
        ..EngineConfig::default()
    })
    .unwrap();
    let x = vec![3.0f32; 4];

    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 1.0));

    // swap to a slow v2, queue traffic against it, then roll back while
    // that traffic is still in flight
    engine.swap_model("echo", VersionedEcho::arc(2.0, 5), Some(2)).unwrap();
    let inflight: Vec<_> = (0..4)
        .map(|_| engine.submit(InferRequest::new("echo", x.clone())).unwrap())
        .collect();
    let epoch = engine.rollback("echo").unwrap();
    assert_eq!(epoch, 2);

    // v2-admitted traffic still completes on v2 — zero drops
    for t in inflight {
        assert_eq!(engine.wait(t).unwrap(), scaled(&x, 2.0));
    }
    // new traffic is back on v1
    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 1.0));
    let lineage = engine.versions("echo").unwrap();
    assert_eq!(
        (lineage[0].store_version, lineage[0].live),
        (Some(1), true)
    );
    assert_eq!((lineage[1].store_version, lineage[1].live), (Some(2), false));

    // rollback toggles: rolling back again returns to v2
    engine.rollback("echo").unwrap();
    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 2.0));

    let s = wait_for_stats(&engine, "echo", "all epochs retired", |s| {
        s.epochs_retired == 3
    });
    assert_eq!((s.swaps, s.rollbacks), (1, 2));
    assert_eq!(s.submitted, s.completed);
    assert_eq!(s.failed + s.expired, 0);
}

#[test]
fn swap_and_rollback_reject_typed() {
    let mut reg = ModelRegistry::new();
    reg.register_versioned("echo".into(), VersionedEcho::arc(1.0, 0), None)
        .unwrap();
    let engine = ServingEngine::new(reg, EngineConfig::default()).unwrap();

    assert_eq!(
        engine.swap_model("ghost", VersionedEcho::arc(2.0, 0), None),
        Err(ServingError::UnknownModel("ghost".into()))
    );
    assert_eq!(
        engine.rollback("ghost"),
        Err(ServingError::UnknownModel("ghost".into()))
    );
    // a model that has never been swapped has nothing to roll back to
    assert_eq!(
        engine.rollback("echo"),
        Err(ServingError::NoPreviousVersion("echo".into()))
    );
    assert!(engine.versions("ghost").is_none());
    // failed control-plane calls did not move the epoch
    assert_eq!(engine.epoch(), 0);
}

#[test]
fn superseded_backends_are_reclaimed_after_drain() {
    let b1 = VersionedEcho::arc(1.0, 0);
    let weak1 = Arc::downgrade(&b1);
    let mut reg = ModelRegistry::new();
    reg.register_versioned("echo".into(), b1, Some(1)).unwrap();
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 4,
        max_wait: Duration::ZERO,
        queue_cap: 64,
        ..EngineConfig::default()
    })
    .unwrap();
    let x = vec![1.0f32; 4];
    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 1.0));

    let b2 = VersionedEcho::arc(2.0, 0);
    let weak2 = Arc::downgrade(&b2);
    engine.swap_model("echo", b2, Some(2)).unwrap();
    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 2.0));
    // v1 is still pinned — it is the rollback target
    assert!(weak1.upgrade().is_some());

    engine.swap_model("echo", VersionedEcho::arc(3.0, 0), Some(3)).unwrap();
    assert_eq!(engine.infer_sync(InferRequest::new("echo", x.clone())).unwrap(),
               scaled(&x, 3.0));

    // v1 left the prev slot and its traffic has drained: its last Arc
    // must drop (the dispatch thread may hold it a beat longer)
    let mut reclaimed = false;
    for _ in 0..2000 {
        if weak1.upgrade().is_none() {
            reclaimed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(reclaimed, "superseded v1 backend still referenced");
    // v2 remains pinned as the current rollback target
    assert!(weak2.upgrade().is_some());

    let s = wait_for_stats(&engine, "echo", "retire", |s| s.epochs_retired == 2);
    assert_eq!(s.swaps, 2);
    assert_eq!(s.submitted, s.completed);
}
