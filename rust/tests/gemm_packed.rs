//! Property tests pinning the packed cache-blocked GEMM family against
//! the naive reference kernels on adversarial shapes, in all three
//! layouts, serial and `_par` at pool widths {1, 2, 4, 8}.
//!
//! Two distinct claims, tested separately:
//! * packed vs naive is **tolerance-checked** — the packed kernel sums
//!   k in KC blocks combined in ascending order while the naive loop
//!   skips zero multiplicands, so results agree to rounding, not bits;
//! * `_par` vs serial packed is **bit-identical** — a row's reduction
//!   order is a fixed function of the inner dimension alone, never of
//!   how rows were split across lanes (the serving engine's
//!   batched-equals-serial contract rides on this).
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::tensor::{self, Epilogue, KC, MC, MR, NC, NR};
use admm_nn::util::{Rng, ThreadPool};

/// Relative-tolerance agreement for packed-vs-naive comparisons.
fn close(a: f32, b: f32) -> bool {
    (a - b).abs() <= 1e-4 * (1.0 + b.abs())
}

fn assert_close(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(close(*g, *w), "{what}[{i}]: packed {g} vs ref {w}");
    }
}

/// Adversarial dimension values: degenerate (0, 1), straddling the
/// register microkernel (MR±1, NR±1), and a non-multiple of everything.
const SMALL_DIMS: [usize; 7] = [0, 1, MR - 1, MR + 1, NR - 1, NR + 1, 13];

/// Shapes straddling the cache-block edges (MC/KC/NC ± 1, exact
/// multiples) — too big for a full cross product, probed directly.
const BIG_SHAPES: [(usize, usize, usize); 5] = [
    (MC + 1, KC + 1, NR + 1),
    (MR + 1, KC + 1, NC + 1),
    (MC + 1, 7, NC + 1),
    (13, KC - 1, 29),
    (MC, KC, NR),
];

fn pools() -> Vec<ThreadPool> {
    [1usize, 2, 4, 8].iter().map(|&w| ThreadPool::new(w)).collect()
}

/// Run one (d0, d1, d2) shape through every layout: serial packed vs
/// the naive reference (tolerance), then `_par` at each pool width vs
/// the serial packed output (bit-identical).
fn check_shape(rng: &mut Rng, pools: &[ThreadPool], d0: usize, d1: usize, d2: usize) {
    // gemm: (d0 × d1) · (d1 × d2)
    let (m, k, n) = (d0, d1, d2);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let mut want = vec![0.0f32; m * n];
    tensor::gemm_ref(&a, &b, m, k, n, &mut want);
    let mut got = vec![1.0f32; m * n];
    tensor::gemm(&a, &b, m, k, n, &mut got);
    assert_close(&got, &want, &format!("gemm {m}x{k}x{n}"));
    for pool in pools {
        let mut par = vec![2.0f32; m * n];
        tensor::gemm_par(pool, &a, &b, m, k, n, &mut par);
        assert_eq!(
            par,
            got,
            "gemm_par {m}x{k}x{n} width {} drifted from serial",
            pool.threads()
        );
    }

    // gemm_tn: A is (d0 × d1), out = Aᵀ · B is (d1 × d2), B (d0 × d2)
    let (m, k, n) = (d0, d1, d2);
    let a = rng.normal_vec(m * k, 0.5);
    let b = rng.normal_vec(m * n, 0.5);
    let mut want = vec![0.0f32; k * n];
    tensor::gemm_tn_ref(&a, &b, m, k, n, &mut want);
    let mut got = vec![1.0f32; k * n];
    tensor::gemm_tn(&a, &b, m, k, n, &mut got);
    assert_close(&got, &want, &format!("gemm_tn {m}x{k}x{n}"));
    for pool in pools {
        let mut par = vec![2.0f32; k * n];
        tensor::gemm_tn_par(pool, &a, &b, m, k, n, &mut par);
        assert_eq!(
            par,
            got,
            "gemm_tn_par {m}x{k}x{n} width {} drifted from serial",
            pool.threads()
        );
    }

    // gemm_nt: A (d0 × d1), B (d2 × d1), out = A · Bᵀ is (d0 × d2)
    let (m, n, k) = (d0, d1, d2);
    let a = rng.normal_vec(m * n, 0.5);
    let b = rng.normal_vec(k * n, 0.5);
    let mut want = vec![0.0f32; m * k];
    tensor::gemm_nt_ref(&a, &b, m, n, k, &mut want);
    let mut got = vec![1.0f32; m * k];
    tensor::gemm_nt(&a, &b, m, n, k, &mut got);
    assert_close(&got, &want, &format!("gemm_nt {m}x{n}x{k}"));
    for pool in pools {
        let mut par = vec![2.0f32; m * k];
        tensor::gemm_nt_par(pool, &a, &b, m, n, k, &mut par);
        assert_eq!(
            par,
            got,
            "gemm_nt_par {m}x{n}x{k} width {} drifted from serial",
            pool.threads()
        );
    }
}

#[test]
fn packed_gemm_matches_naive_on_adversarial_small_shapes() {
    let mut rng = Rng::new(0xACC);
    let pools = pools();
    for &d0 in &SMALL_DIMS {
        for &d1 in &SMALL_DIMS {
            for &d2 in &SMALL_DIMS {
                check_shape(&mut rng, &pools, d0, d1, d2);
            }
        }
    }
}

#[test]
fn packed_gemm_matches_naive_across_cache_block_edges() {
    let mut rng = Rng::new(0xB10C);
    let pools = pools();
    for &(d0, d1, d2) in &BIG_SHAPES {
        check_shape(&mut rng, &pools, d0, d1, d2);
    }
}

/// The fused bias / bias+ReLU epilogue applies the same f32 operations
/// in the same order as the unfused two-pass form (GEMM, then separate
/// bias and clamp sweeps), so the results are bit-identical — and the
/// `_par` fused path matches the serial fused path exactly.
#[test]
fn fused_epilogue_equals_unfused_two_pass() {
    let mut rng = Rng::new(0xE91);
    let pools = pools();
    for (m, k, n) in [
        (1usize, 1usize, 1usize),
        (MR + 1, 13, NR + 1),
        (MC + 1, KC + 1, NR - 1),
        (7, 0, 5),
    ] {
        let a = rng.normal_vec(m * k, 0.5);
        let b = rng.normal_vec(k * n, 0.5);
        let bias = rng.normal_vec(n, 0.5);

        let mut two_pass = vec![0.0f32; m * n];
        tensor::gemm(&a, &b, m, k, n, &mut two_pass);
        let mut bias_only = two_pass.clone();
        for row in bias_only.chunks_mut(n) {
            for (v, &bv) in row.iter_mut().zip(&bias) {
                *v += bv;
            }
        }
        let mut bias_relu = bias_only.clone();
        for v in bias_relu.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }

        let mut fused = vec![9.0f32; m * n];
        tensor::gemm_epi(&a, &b, m, k, n, Epilogue::Bias(&bias), &mut fused);
        assert_eq!(fused, bias_only, "Bias epilogue {m}x{k}x{n}");
        tensor::gemm_epi(&a, &b, m, k, n, Epilogue::BiasRelu(&bias), &mut fused);
        assert_eq!(fused, bias_relu, "BiasRelu epilogue {m}x{k}x{n}");

        for pool in &pools {
            let mut par = vec![8.0f32; m * n];
            tensor::gemm_par_epi(
                pool,
                &a,
                &b,
                m,
                k,
                n,
                Epilogue::BiasRelu(&bias),
                &mut par,
            );
            assert_eq!(
                par,
                bias_relu,
                "par BiasRelu {m}x{k}x{n} width {}",
                pool.threads()
            );
        }
    }
}
