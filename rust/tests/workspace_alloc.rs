//! Workspace-reuse instrumentation: the zero-alloc claim for the hot
//! paths, pinned by growth counters instead of a counting allocator
//! (the pool's scoped closures box on spawn, so raw allocation counts
//! would measure the harness, not the kernels).
//!
//! Three counters, one claim each:
//! * `NativeBackend::scratch_grow_count` — the train step's arenas
//!   (im2col columns, activations, tape copies, gradients — the
//!   caller-side workspace plus every per-shard slot of the sharded
//!   train/eval fan-out, summed) stop growing once warm;
//! * `SparseInfer::scratch_grow_count` — the serving batch's arena
//!   (im2col columns, activations, argmax maps) stops growing once
//!   warm;
//! * `tensor::pack_grow_count` — the per-thread GEMM pack buffers are
//!   sized to the fixed MC·KC / KC·NC cache blocks, so each worker
//!   grows them once, ever.
//!
//! This file deliberately holds a SINGLE test: `pack_grow_count` is a
//! process-global counter, and unrelated tests running GEMMs in
//! parallel inside the same binary would race the snapshots. As its own
//! integration-test binary it owns the process.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::backend::native::NativeBackend;
use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
use admm_nn::backend::{Hyper, ModelExec, TrainState};
use admm_nn::data::{self, Dataset, Split};
use admm_nn::tensor;
use admm_nn::util::ThreadPool;

#[test]
fn steady_state_hot_paths_stop_growing_workspaces() {
    // -- native train path: conv + pool + dense, forward and backward --
    let nb = NativeBackend::open_with_batches("lenet5", 8, 8).unwrap();
    let ds = data::for_input_shape(&nb.entry().input_shape);
    let mut st = TrainState::init(nb.entry(), 5);
    let hyper = Hyper::default();
    let batch = ds.batch(Split::Train, 0, 8);
    // Warmup: fixed shapes mean the take sequence repeats every step,
    // so capacities are nondecreasing and bounded — a few steps reach
    // the fixed point (extra steps cover lane→thread reassignment in
    // the pool warming more than one thread's pack buffers).
    for _ in 0..5 {
        nb.train_step(&mut st, &hyper, &batch).unwrap();
    }
    let native_grows = nb.scratch_grow_count();
    let pack_grows = tensor::pack_grow_count();
    for _ in 0..3 {
        nb.train_step(&mut st, &hyper, &batch).unwrap();
    }
    assert_eq!(
        nb.scratch_grow_count(),
        native_grows,
        "steady-state train step reallocated workspace buffers"
    );
    assert_eq!(
        tensor::pack_grow_count(),
        pack_grows,
        "steady-state train step regrew GEMM pack buffers"
    );

    // -- sharded evaluate on the same backend: shard `s` always leases
    //    workspace slot `s` (the partition is fixed by the batch size),
    //    so the per-slot arenas see the same take/put sequence every
    //    pass and the eval path goes flat after one warmup pass too --
    for _ in 0..2 {
        nb.evaluate(&st, &*ds, 2).unwrap();
    }
    let native_grows = nb.scratch_grow_count();
    let pack_grows = tensor::pack_grow_count();
    for _ in 0..3 {
        nb.evaluate(&st, &*ds, 2).unwrap();
    }
    assert_eq!(
        nb.scratch_grow_count(),
        native_grows,
        "steady-state sharded evaluate reallocated workspace buffers"
    );
    assert_eq!(
        tensor::pack_grow_count(),
        pack_grows,
        "steady-state sharded evaluate regrew GEMM pack buffers"
    );

    // -- sparse serving path: conv, skip save/add, projection shortcut,
    //    GAP head — the full residual op set drawing on the arena --
    let nb = NativeBackend::open_with_batches("resnet_proxy", 4, 4).unwrap();
    let mut st = TrainState::init(nb.entry(), 7);
    let model =
        prune_quantize_package(nb.entry(), "resnet_proxy", &mut st, 0.3, 4, 8);
    let sp = SparseInfer::new(&model, nb.entry()).unwrap();
    let ds = data::for_input_shape(&nb.entry().input_shape);
    let batch = ds.batch(Split::Test, 0, 4);
    let pool = ThreadPool::new(2);
    for _ in 0..4 {
        sp.infer_with(&pool, &batch.x, 4).unwrap();
    }
    let sparse_grows = sp.scratch_grow_count();
    let pack_grows = tensor::pack_grow_count();
    for _ in 0..3 {
        sp.infer_with(&pool, &batch.x, 4).unwrap();
    }
    assert_eq!(
        sp.scratch_grow_count(),
        sparse_grows,
        "steady-state serving batch reallocated workspace buffers"
    );
    assert_eq!(
        tensor::pack_grow_count(),
        pack_grows,
        "steady-state serving batch regrew GEMM pack buffers"
    );
}
