//! Property tests for the projection-engine and persistent-pool PRs:
//! every converted hot path must agree with its seed counterpart.
//!
//! * `_into` / in-place projection variants are bit-identical to the
//!   allocating ones on random vectors (including dirty reused buffers);
//! * the blocked magnitude top-k select is bit-identical to the PR-1
//!   index-indirect select, ties included;
//! * the histogram quantizer search agrees with the exact golden-section
//!   search to ≤ 1% relative error in the final `QuantConfig::error`
//!   across bit-widths 1–8;
//! * per-layer parallel projection — including the persistent pool's
//!   size-aware split of a dominant layer across idle workers — produces
//!   results identical to the serial path at widths {1, 2, 4, 8};
//! * the parallel blocked top-k select (`prune_topk_into_par`) is
//!   bit-identical to the serial select at widths {1, 2, 4, 8} — tie
//!   storms, the k edge set {0, 1, n−1, n}, and NaN inputs included —
//!   and the chunked map-reduce primitives it runs on honor the pool's
//!   nested-fan-out contract;
//! * parallel `RelIndex` packaging stores byte-identical encodings;
//! * the fused dual update reproduces the composed tensor ops exactly.
//!
//! Pure host code — no PJRT artifacts required.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::coordinator::Constraint;
use admm_nn::projection::{self, ProjectionWorkspace};
use admm_nn::quantize::{self, QuantConfig};
use admm_nn::sparsity::RelIndex;
use admm_nn::tensor::Tensor;
use admm_nn::util::{Rng, ThreadPool};

/// Random layer mix: dense, post-prune sparse, tiny, and all-zero.
fn random_layers(seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    let mut layers = vec![
        rng.normal_vec(10_000, 0.1),
        projection::prune_topk(&rng.normal_vec(20_000, 0.05), 1_000),
        rng.normal_vec(33, 1.0),
        vec![0.0f32; 64],
    ];
    // a heavy-tailed layer (cubed gaussians)
    layers.push(rng.normal_vec(5_000, 1.0).iter().map(|&x| x * x * x).collect());
    layers
}

#[test]
fn into_variants_bit_identical_on_random_vectors() {
    let mut rng = Rng::new(100);
    let mut idx = Vec::new();
    let mut out = Vec::new();
    for trial in 0..20 {
        let n = 100 + rng.below(5000);
        let v = rng.normal_vec(n, 0.5);
        let k = rng.below(n + 1);

        projection::prune_topk_into(&v, k, &mut idx, &mut out);
        assert_eq!(out, projection::prune_topk(&v, k), "trial {trial} prune");

        let q = 0.01 + rng.uniform() as f32 * 0.2;
        let half_m = 1 + rng.below(128) as u32;
        projection::quant_nearest_into(&v, q, half_m, &mut out);
        let want = projection::quant_nearest(&v, q, half_m);
        assert_eq!(out, want, "trial {trial} quant");
        let mut inplace = v.clone();
        projection::quant_nearest_inplace(&mut inplace, q, half_m);
        assert_eq!(inplace, want, "trial {trial} quant inplace");

        projection::joint_project_into(&v, k, q, half_m, &mut idx, &mut out);
        assert_eq!(
            out,
            projection::joint_project(&v, k, q, half_m),
            "trial {trial} joint"
        );
    }
}

#[test]
fn histogram_search_within_one_percent_of_exact() {
    for (li, v) in random_layers(7).iter().enumerate() {
        for bits in 1..=8u32 {
            let h = quantize::search_interval(v, bits);
            let e = quantize::search_interval_exact(v, bits);
            let tol = e.error * 0.01 + 1e-12;
            assert!(
                (h.error - e.error).abs() <= tol,
                "layer {li} bits={bits}: histogram {} vs exact {}",
                h.error,
                e.error
            );
        }
    }
}

#[test]
fn parallel_constraint_projection_identical_to_serial() {
    let layers = random_layers(8);
    let keep: Vec<usize> = layers.iter().map(|l| l.len() / 3).collect();
    let configs: Vec<QuantConfig> = layers
        .iter()
        .map(|l| quantize::search_interval(l, 4))
        .collect();
    for constraint in [
        Constraint::Cardinality { keep },
        Constraint::Levels { configs },
    ] {
        let serial: Vec<Vec<f32>> = layers
            .iter()
            .enumerate()
            .map(|(li, l)| constraint.project(li, l))
            .collect();
        for threads in [1usize, 2, 3, 8] {
            let pool = ThreadPool::new(threads);
            let mut wss: Vec<ProjectionWorkspace> = Vec::new();
            let jobs: Vec<(usize, &Vec<f32>)> = layers.iter().enumerate().collect();
            let parallel = pool.map_with_scratch(
                jobs,
                &mut wss,
                ProjectionWorkspace::new,
                |_, (li, l), ws| {
                    constraint.project_with(li, l, ws);
                    ws.out.clone()
                },
            );
            assert_eq!(serial, parallel, "threads={threads}");
        }
    }
}

#[test]
fn blocked_topk_select_matches_index_select_on_layer_mix() {
    let mut mags = Vec::new();
    let mut idx = Vec::new();
    let (mut blocked, mut indexsel) = (Vec::new(), Vec::new());
    for (li, v) in random_layers(21).iter().enumerate() {
        for k in [0usize, 1, v.len() / 7, v.len() / 2, v.len()] {
            projection::prune_topk_into(v, k, &mut mags, &mut blocked);
            projection::prune_topk_into_indexsel(v, k, &mut idx, &mut indexsel);
            assert_eq!(blocked, indexsel, "layer {li} k={k}");
        }
    }
}

#[test]
fn size_aware_dominant_layer_split_identical_to_serial() {
    // One dominant fc layer (big enough that its Levels projection
    // splits elementwise across idle workers from inside the per-layer
    // fan-out) among small siblings: results must be bit-identical to
    // the serial path at every pool width.
    let mut rng = Rng::new(31);
    let mut layers: Vec<Vec<f32>> = vec![rng.normal_vec(300_000, 0.1)];
    for n in [500usize, 3_000, 64, 1_200] {
        layers.push(rng.normal_vec(n, 0.3));
    }
    let sizes: Vec<usize> = layers.iter().map(|l| l.len()).collect();
    let configs: Vec<QuantConfig> = layers
        .iter()
        .map(|l| quantize::search_interval(l, 4))
        .collect();
    let constraint = Constraint::Levels { configs };
    let serial: Vec<Vec<f32>> = layers
        .iter()
        .enumerate()
        .map(|(li, l)| constraint.project(li, l))
        .collect();
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let mut wss: Vec<ProjectionWorkspace> = Vec::new();
        let jobs: Vec<(usize, &Vec<f32>)> = layers.iter().enumerate().collect();
        let parallel = pool.map_with_scratch_sized(
            jobs,
            &sizes,
            &mut wss,
            ProjectionWorkspace::new,
            |_, (li, l), ws| {
                constraint.project_with(li, l, ws);
                ws.out.clone()
            },
        );
        assert_eq!(serial, parallel, "threads={threads}");
    }
    // Production path: fan out over the *global* pool, so the dominant
    // layer's nested Levels projection splits across that same pool's
    // idle workers (on a foreign pool, as above, it runs inline).
    let mut wss: Vec<ProjectionWorkspace> = Vec::new();
    let jobs: Vec<(usize, &Vec<f32>)> = layers.iter().enumerate().collect();
    let global = ThreadPool::global().map_with_scratch_sized(
        jobs,
        &sizes,
        &mut wss,
        ProjectionWorkspace::new,
        |_, (li, l), ws| {
            constraint.project_with(li, l, ws);
            ws.out.clone()
        },
    );
    assert_eq!(serial, global, "global pool");
}

/// Bitwise slice equality (NaN-tolerant; `assert_eq!` on f32 rejects
/// NaN == NaN).
fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: index {i}: {x} vs {y}");
    }
}

#[test]
fn parallel_blocked_select_property_suite() {
    // The deterministic parallel partition select must be bit-identical
    // to the serial blocked select at every pool width, over the layer
    // mix, a dominant layer with coarse ties, constant-input tie
    // storms, the k edge set, and NaN inputs.
    let mut rng = Rng::new(41);
    let mut inputs = random_layers(40);
    // dominant layer with frequent exact ties across block boundaries
    inputs.push(
        rng.normal_vec(250_000, 1.0)
            .iter()
            .map(|&x| (x * 4.0).round() / 4.0)
            .collect(),
    );
    inputs.push(vec![0.5f32; 100_000]); // constant tie storm
    let mut nanny = rng.normal_vec(150_000, 1.0);
    nanny[0] = f32::NAN;
    nanny[74_000] = f32::NAN;
    inputs.push(nanny);
    let mut mags = Vec::new();
    let (mut serial, mut par) = (Vec::new(), Vec::new());
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        for (li, v) in inputs.iter().enumerate() {
            let n = v.len();
            for k in [0usize, 1, n / 7, n / 2, n.saturating_sub(1), n] {
                projection::prune_topk_into(v, k, &mut mags, &mut serial);
                projection::prune_topk_into_par(&pool, v, k, &mut mags, &mut par);
                assert_bits_eq(&serial, &par, &format!("threads={threads} layer {li} k={k}"));
            }
        }
    }
}

#[test]
fn cardinality_dominant_layer_split_identical_to_serial() {
    // The production Z-update shape for pruning: one dominant fc layer
    // among small siblings, projected through Constraint::Cardinality
    // inside a per-layer fan-out. On the global pool the dominant
    // layer's blocked select splits across idle workers; results must
    // be bit-identical to the serial path either way.
    let mut rng = Rng::new(42);
    let mut layers: Vec<Vec<f32>> = vec![rng.normal_vec(300_000, 0.1)];
    for n in [700usize, 2_500, 96, 1_800] {
        layers.push(rng.normal_vec(n, 0.3));
    }
    let sizes: Vec<usize> = layers.iter().map(|l| l.len()).collect();
    let keep: Vec<usize> = sizes.iter().map(|&n| n / 11).collect();
    let constraint = Constraint::Cardinality { keep };
    let serial: Vec<Vec<f32>> = layers
        .iter()
        .enumerate()
        .map(|(li, l)| constraint.project(li, l))
        .collect();
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let mut wss: Vec<ProjectionWorkspace> = Vec::new();
        let jobs: Vec<(usize, &Vec<f32>)> = layers.iter().enumerate().collect();
        let parallel = pool.map_with_scratch_sized(
            jobs,
            &sizes,
            &mut wss,
            ProjectionWorkspace::new,
            |_, (li, l), ws| {
                constraint.project_with(li, l, ws);
                ws.out.clone()
            },
        );
        assert_eq!(serial, parallel, "threads={threads}");
    }
    // production path: global pool, nested split over its idle workers
    let mut wss: Vec<ProjectionWorkspace> = Vec::new();
    let jobs: Vec<(usize, &Vec<f32>)> = layers.iter().enumerate().collect();
    let global = ThreadPool::global().map_with_scratch_sized(
        jobs,
        &sizes,
        &mut wss,
        ProjectionWorkspace::new,
        |_, (li, l), ws| {
            constraint.project_with(li, l, ws);
            ws.out.clone()
        },
    );
    assert_eq!(serial, global, "global pool");
}

#[test]
fn chunk_primitives_honor_nested_fanout_contract() {
    // par_chunk_map / par_chunk_zip from inside a lane of a *foreign*
    // pool must run inline (plan_split = 1) and still be correct; from
    // the same pool they may split across idle workers only. Either
    // way the merged result equals the serial computation.
    let src: Vec<f32> = (0..200_000).map(|i| (i % 977) as f32 * 0.25).collect();
    let want_sum: f64 = src.iter().map(|&x| x as f64).sum();
    let outer = ThreadPool::new(4);
    let sums = outer.map_with_scratch(
        vec![0usize, 1],
        &mut Vec::new(),
        || (),
        |_, job, _| {
            let foreign = ThreadPool::new(8);
            let blocks = foreign.plan_split(src.len());
            assert_eq!(blocks, 1, "foreign-pool chunk split must be inline");
            if job == 0 {
                // read pass, serial merge in block order
                foreign
                    .par_chunk_map(src.len(), blocks, |_, r| {
                        src[r].iter().map(|&x| x as f64).sum::<f64>()
                    })
                    .into_iter()
                    .sum::<f64>()
            } else {
                let mut dst = vec![0.0f32; src.len()];
                foreign.par_chunk_zip(&src, &mut dst, blocks, |_, ss, ds| {
                    for (d, &s) in ds.iter_mut().zip(ss) {
                        *d = s;
                    }
                });
                dst.iter().map(|&x| x as f64).sum::<f64>()
            }
        },
    );
    assert_eq!(sums, vec![want_sum, want_sum]);
    // same-pool split from the top level: blocks > 1, same serial-merge
    // result because block order is preserved.
    let pool = ThreadPool::new(4);
    let blocks = pool.plan_split(src.len());
    assert!(blocks > 1, "top-level split should fan out");
    let per_block = pool.par_chunk_map(src.len(), blocks, |b, r| {
        (b, src[r].iter().map(|&x| x as f64).sum::<f64>())
    });
    assert!(per_block.iter().enumerate().all(|(i, (b, _))| i == *b));
    // serial in-order merge is deterministic at any width: compare to a
    // 2-wide pool's merge of the same partition plan
    let sum4: f64 = per_block.iter().map(|(_, s)| s).sum();
    let pool2 = ThreadPool::new(2);
    let sum2: f64 = pool2
        .par_chunk_map(src.len(), blocks, |_, r| {
            src[r].iter().map(|&x| x as f64).sum::<f64>()
        })
        .into_iter()
        .sum();
    assert_eq!(sum4, sum2, "same partition, same merge order, same bits");
}

#[test]
fn parallel_relindex_packaging_identical_to_serial() {
    // The CompressedModel packaging fan-out must store exactly the same
    // encoding the serial loop produced, layer order preserved.
    let mut rng = Rng::new(32);
    let codes_per_layer: Vec<Vec<i32>> = (0..7)
        .map(|i| {
            let n = 5_000 + 11_000 * i;
            let w = projection::prune_topk(&rng.normal_vec(n, 0.1), n / 15);
            let c = quantize::search_interval(&w, 3);
            quantize::encode_levels(&c.apply(&w), &c)
        })
        .collect();
    let sizes: Vec<usize> = codes_per_layer.iter().map(|c| c.len()).collect();
    let serial: Vec<RelIndex> = codes_per_layer
        .iter()
        .map(|c| RelIndex::encode(c, 4))
        .collect();
    for threads in [1usize, 2, 4, 8] {
        let pool = ThreadPool::new(threads);
        let parallel = pool.map_with_scratch_sized(
            codes_per_layer.iter().collect::<Vec<&Vec<i32>>>(),
            &sizes,
            &mut Vec::new(),
            || (),
            |_, c, _| RelIndex::encode(c, 4),
        );
        for (li, (a, b)) in serial.iter().zip(&parallel).enumerate() {
            assert_eq!(a.entries, b.entries, "threads={threads} layer={li}");
            assert_eq!(a.dense_len, b.dense_len, "threads={threads} layer={li}");
            assert_eq!(a.index_bits, b.index_bits);
        }
    }
}

#[test]
fn fused_dual_update_equals_seed_composition() {
    let mut rng = Rng::new(9);
    for n in [1usize, 100, 40_000] {
        let w = Tensor::new(vec![n], rng.normal_vec(n, 0.7));
        let z = Tensor::new(vec![n], rng.normal_vec(n, 0.7));
        let mut u_seed = Tensor::new(vec![n], rng.normal_vec(n, 0.1));
        let mut u_fused = u_seed.clone();

        u_seed.add_assign(&w.sub(&z));
        let resid_seed = w.sub(&z).sq_norm();
        let resid_fused = u_fused.dual_update(&w, &z);

        assert_eq!(u_seed.data(), u_fused.data(), "n={n}");
        assert_eq!(resid_seed, resid_fused, "n={n}");
    }
}

#[test]
fn workspace_reuse_across_mismatched_layers_is_clean() {
    // A dirty workspace from a big layer must not leak into a small one.
    let mut ws = ProjectionWorkspace::new();
    let big = Constraint::Cardinality { keep: vec![500] };
    let mut rng = Rng::new(10);
    let vbig = rng.normal_vec(4_000, 1.0);
    big.project_with(0, &vbig, &mut ws);
    assert_eq!(ws.out.len(), 4_000);

    let small = Constraint::Levels {
        configs: vec![QuantConfig { bits: 2, q: 0.5, error: 0.0 }],
    };
    let vsmall = [0.3f32, -1.2, 0.0];
    small.project_with(0, &vsmall, &mut ws);
    assert_eq!(ws.out, small.project(0, &vsmall));
    assert_eq!(ws.out.len(), 3);
}
