//! Determinism contract of the synthetic datasets.
//!
//! Everything downstream leans on batches being pure functions of
//! (split, index, batch size): the native and PJRT backends must see
//! identical data, hw-aware probe counting compares batch totals across
//! runs, and every training test is reproducible only if the dataset
//! is. These tests pin the contract explicitly for both datasets:
//! identical (split, index, batch-size) triples yield identical batches
//! across repeated calls, across fresh dataset instances, and
//! regardless of what other batches were drawn in between (no hidden
//! iteration state).
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::data::{Batch, Dataset, Split, SyntheticDigits, SyntheticImages};

fn assert_batch_eq(a: &Batch, b: &Batch, what: &str) {
    assert_eq!(a.x, b.x, "{what}: x differs");
    assert_eq!(a.y, b.y, "{what}: y differs");
    assert_eq!(a.batch, b.batch, "{what}: batch size differs");
    assert_eq!(a.input_shape, b.input_shape, "{what}: shape differs");
}

fn check_identical_across_calls(ds: &dyn Dataset, what: &str) {
    for split in [Split::Train, Split::Test] {
        for index in [0u64, 1, 17, 1_000_003] {
            for bsz in [1usize, 3, 16] {
                let a = ds.batch(split, index, bsz);
                let b = ds.batch(split, index, bsz);
                assert_batch_eq(&a, &b, what);
            }
        }
    }
}

fn check_call_order_invariance(ds: &dyn Dataset, what: &str) {
    // reference draws, one per (split, index, size)
    let r1 = ds.batch(Split::Train, 5, 8);
    let r2 = ds.batch(Split::Test, 2, 4);
    // interleave a pile of unrelated draws in a different order
    let _ = ds.batch(Split::Test, 9, 16);
    let _ = ds.batch(Split::Train, 5, 3); // same index, different size
    let _ = ds.batch(Split::Train, 0, 8);
    let _ = ds.batch(Split::Test, 2, 16);
    // the original draws must be unchanged
    assert_batch_eq(&r1, &ds.batch(Split::Train, 5, 8), what);
    assert_batch_eq(&r2, &ds.batch(Split::Test, 2, 4), what);
}

#[test]
fn digits_identical_across_calls_and_instances() {
    let ds = SyntheticDigits::standard();
    check_identical_across_calls(&ds, "digits");
    // a fresh instance with the same config is the same dataset
    let fresh = SyntheticDigits::standard();
    assert_batch_eq(
        &ds.batch(Split::Train, 11, 8),
        &fresh.batch(Split::Train, 11, 8),
        "digits across instances",
    );
}

#[test]
fn digits_invariant_to_call_order() {
    check_call_order_invariance(&SyntheticDigits::standard(), "digits");
}

#[test]
fn images_identical_across_calls_and_instances() {
    let ds = SyntheticImages::standard();
    check_identical_across_calls(&ds, "images");
    let fresh = SyntheticImages::standard();
    assert_batch_eq(
        &ds.batch(Split::Test, 7, 4),
        &fresh.batch(Split::Test, 7, 4),
        "images across instances",
    );
}

#[test]
fn images_invariant_to_call_order() {
    check_call_order_invariance(&SyntheticImages::standard(), "images");
}

#[test]
fn distinct_coordinates_yield_distinct_batches() {
    // not a determinism property per se, but the sanity complement: the
    // (split, index) coordinates actually select different data.
    let ds = SyntheticDigits::standard();
    let base = ds.batch(Split::Train, 0, 8);
    assert_ne!(base.x, ds.batch(Split::Train, 1, 8).x, "index ignored");
    assert_ne!(base.x, ds.batch(Split::Test, 0, 8).x, "split ignored");
    let imgs = SyntheticImages::standard();
    let ibase = imgs.batch(Split::Train, 0, 2);
    assert_ne!(ibase.x, imgs.batch(Split::Train, 1, 2).x, "index ignored");
    assert_ne!(ibase.x, imgs.batch(Split::Test, 0, 2).x, "split ignored");
}
