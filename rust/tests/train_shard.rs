//! Width-invariance property suite for data-parallel sharded training:
//! `NativeBackend::train_step` and `evaluate` must be **bit-identical**
//! at pool widths {1, 2, 4, 8}, across all five proxies, including
//! batch sizes that do not divide evenly by any lane count (1, lanes±1,
//! prime).
//!
//! Why this must hold by construction (and what the test pins): the
//! shard partition is a function of the batch size alone
//! (`util::shard_count` / `util::shard_range` — never of pool width or
//! scheduling order), every cross-shard reduction merges serially in
//! ascending shard index, and the per-shard GEMMs honor the tensor
//! module's width-invariant reduction-order contract. A width-1 pool —
//! exactly what `ADMM_NN_THREADS=1` makes the global pool
//! (`util::pool`'s `env_width_parsing` / `width_one_runs_inline…` tests
//! pin that mapping) — runs the very same shard loop inline on the
//! caller, so the width-1 column below *is* the documented serial
//! fallback, and every other width is asserted bit-equal to it.
//!
//! Correctness against the unsharded math (different summation tree,
//! tolerance-level agreement) is covered by the reference test in
//! `backend/native.rs` and by the central-difference gradchecks, which
//! run against this same sharded path.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::backend::native::NativeBackend;
use admm_nn::backend::{Hyper, ModelExec, StepStats, TrainState};
use admm_nn::data::{self, Dataset, Split};
use admm_nn::metrics::EvalStats;
use admm_nn::util::{Rng, ThreadPool};

const WIDTHS: [usize; 4] = [1, 2, 4, 8];

/// Open `name` with train/eval batch `bsz`, pinned to a width-`w` pool.
fn open(name: &str, bsz: usize, width: usize) -> NativeBackend {
    NativeBackend::open_with_batches(name, bsz, bsz)
        .unwrap()
        .with_pool(ThreadPool::new(width))
}

/// Live ADMM state: random Z/U, nonzero ρ, a partially-zero mask on
/// layer 0 — so the penalty, L1, and mask channels of the fused update
/// all participate in the width-invariance claim, not just the data
/// path.
fn mk_state(nb: &NativeBackend, seed: u64) -> TrainState {
    let mut st = TrainState::init(nb.entry(), seed);
    let mut rng = Rng::new(seed ^ 0xD1CE);
    for li in 0..st.zs.len() {
        let n = st.zs[li].len();
        st.zs[li].copy_from(&rng.normal_vec(n, 0.1));
        st.us[li].copy_from(&rng.normal_vec(n, 0.05));
        st.rhos[li] = 0.4;
    }
    let m0 = st.masks[0].data_mut();
    for i in 0..m0.len() {
        if i % 4 == 0 {
            m0[i] = 0.0;
        }
    }
    st
}

/// Bitwise f32-slice equality (`assert_eq!` on f32 would miss -0.0/NaN
/// distinctions; bit patterns are the actual claim).
fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: index {i}: {x} vs {y}");
    }
}

fn assert_state_bits_eq(a: &TrainState, b: &TrainState, ctx: &str) {
    assert_eq!(a.step.to_bits(), b.step.to_bits(), "{ctx}: step");
    for pi in 0..a.params.len() {
        assert_bits_eq(a.params[pi].data(), b.params[pi].data(), &format!("{ctx}: param {pi}"));
        assert_bits_eq(a.adam_m[pi].data(), b.adam_m[pi].data(), &format!("{ctx}: adam_m {pi}"));
        assert_bits_eq(a.adam_v[pi].data(), b.adam_v[pi].data(), &format!("{ctx}: adam_v {pi}"));
    }
}

fn assert_stats_bits_eq(a: &[StepStats], b: &[StepStats], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: step count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{ctx}: step {i} loss");
        assert_eq!(x.acc.to_bits(), y.acc.to_bits(), "{ctx}: step {i} acc");
    }
}

fn assert_eval_bits_eq(a: &EvalStats, b: &EvalStats, ctx: &str) {
    assert_eq!(a.loss_sum.to_bits(), b.loss_sum.to_bits(), "{ctx}: loss_sum");
    assert_eq!(a.correct.to_bits(), b.correct.to_bits(), "{ctx}: correct");
    assert_eq!(a.samples, b.samples, "{ctx}: samples");
    assert_eq!(a.batches, b.batches, "{ctx}: batches");
}

/// Run `steps` train steps at pool width `width` and return the final
/// state plus the per-step scalars, followed by one evaluate pass.
fn run(
    name: &str,
    bsz: usize,
    steps: usize,
    width: usize,
    seed: u64,
) -> (TrainState, Vec<StepStats>, EvalStats) {
    let nb = open(name, bsz, width);
    let ds = data::for_input_shape(&nb.entry().input_shape);
    let mut st = mk_state(&nb, seed);
    let hyper = Hyper { lr: 1e-3, l1_lambda: 1e-4 };
    let stats: Vec<StepStats> = (0..steps)
        .map(|i| {
            nb.train_step(&mut st, &hyper, &ds.batch(Split::Train, i as u64, bsz))
                .unwrap()
        })
        .collect();
    let eval = nb.evaluate(&st, &ds, 2).unwrap();
    (st, stats, eval)
}

/// The property, for one (model, batch-size) cell: widths {2, 4, 8}
/// reproduce the width-1 serial fallback bit-for-bit — trained
/// parameters, ADAM moments, per-step loss/accuracy scalars, and the
/// evaluate aggregates.
fn check_widths(name: &str, bsz: usize, steps: usize, seed: u64) {
    let (st1, stats1, eval1) = run(name, bsz, steps, 1, seed);
    for width in WIDTHS.iter().skip(1) {
        let (stw, statsw, evalw) = run(name, bsz, steps, *width, seed);
        let ctx = format!("{name} bsz={bsz} width={width}");
        assert_state_bits_eq(&stw, &st1, &ctx);
        assert_stats_bits_eq(&statsw, &stats1, &ctx);
        assert_eval_bits_eq(&evalw, &eval1, &ctx);
    }
}

/// mlp is cheap: sweep the uneven-split batch sizes — 1 (single-row
/// batch, one shard), lanes±1 around every tested width (3, 5, 7), and
/// a prime (13) that divides evenly by no width.
#[test]
fn mlp_width_invariant_at_uneven_batch_sizes() {
    for bsz in [1usize, 3, 5, 7, 13] {
        check_widths("mlp", bsz, 3, 11);
    }
}

#[test]
fn lenet5_width_invariant_at_uneven_batch_sizes() {
    for bsz in [1usize, 5, 7] {
        check_widths("lenet5", bsz, 2, 12);
    }
}

#[test]
fn alexnet_proxy_width_invariant() {
    check_widths("alexnet_proxy", 3, 1, 13);
    check_widths("alexnet_proxy", 1, 1, 13);
}

#[test]
fn vgg_proxy_width_invariant() {
    check_widths("vgg_proxy", 3, 1, 14);
    check_widths("vgg_proxy", 1, 1, 14);
}

#[test]
fn resnet_proxy_width_invariant() {
    // the residual-edge op set (skip save/add, projection shortcuts,
    // GAP head) rides through the same shard loop
    check_widths("resnet_proxy", 3, 1, 15);
    check_widths("resnet_proxy", 1, 1, 15);
}
