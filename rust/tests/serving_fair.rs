//! Property tests of the fair-share scheduling layer: deficit-round-
//! robin weighted shares, per-tenant quotas, deadline-feasibility
//! admission control, the starvation bound, and the sharded completion
//! condvars — at pool widths {1, 2, 4, 8}.
//!
//! The share tests exploit a determinism property of the scheduler:
//! with the backend blocked on a gate, every request can be queued
//! before any post-warmup dispatch happens, after which the DRR ring
//! drains in a fully deterministic order (`max_wait = 0` means no
//! batching holds, and submissions have already stopped). The dispatch
//! log then directly witnesses the weighted interleaving.
// Crate-root style allowances, matching rust/src/lib.rs.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use admm_nn::backend::native::NativeBackend;
use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
use admm_nn::backend::TrainState;
use admm_nn::data::{self, Dataset, Split};
use admm_nn::serving::{
    EngineConfig, InferBackend, InferRequest, ModelRegistry, ServingEngine,
    ServingError, TenantConfig,
};
use admm_nn::util::ThreadPool;

/// Identity backend that records every dispatched batch as
/// `(model name, rows)` and can block inside `infer_batch` on a shared
/// gate — the tool for freezing the scheduler while queues prefill.
struct Gate {
    tag: &'static str,
    dim: usize,
    log: Arc<Mutex<Vec<(&'static str, usize)>>>,
    /// While true, `infer_batch` spins (the scheduler thread is parked
    /// inside the dispatch, so no further batches can be extracted).
    hold: Arc<AtomicBool>,
    /// Set on entry to `infer_batch` — lets the test wait until the
    /// warmup batch is actually in flight before prefilling.
    entered: Arc<AtomicBool>,
}

impl InferBackend for Gate {
    fn name(&self) -> &str {
        self.tag
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.dim
    }

    fn infer_batch(
        &self,
        _pool: &ThreadPool,
        x: &[f32],
        bsz: usize,
    ) -> admm_nn::Result<Vec<f32>> {
        self.log.lock().unwrap().push((self.tag, bsz));
        self.entered.store(true, Ordering::SeqCst);
        while self.hold.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(x.to_vec())
    }
}

/// Identity backend with a fixed per-batch delay — makes queueing (and
/// therefore fairness and feasibility estimates) observable.
struct DelayEcho {
    tag: &'static str,
    dim: usize,
    delay: Duration,
}

impl InferBackend for DelayEcho {
    fn name(&self) -> &str {
        self.tag
    }

    fn input_dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.dim
    }

    fn infer_batch(
        &self,
        _pool: &ThreadPool,
        x: &[f32],
        _bsz: usize,
    ) -> admm_nn::Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        Ok(x.to_vec())
    }
}

/// Build a two-tenant gated engine: "hot" at weight `w_hot`, "cold" at
/// weight 1, shared dispatch log and gate.
#[allow(clippy::type_complexity)]
fn gated_engine(
    width: usize,
    w_hot: u32,
    hot_quota: usize,
) -> (
    ServingEngine,
    Arc<Mutex<Vec<(&'static str, usize)>>>,
    Arc<AtomicBool>,
    Arc<AtomicBool>,
) {
    let log = Arc::new(Mutex::new(Vec::new()));
    let hold = Arc::new(AtomicBool::new(true));
    let entered = Arc::new(AtomicBool::new(false));
    let mut reg = ModelRegistry::new();
    for tag in ["hot", "cold"] {
        reg.register_named(
            tag.into(),
            Arc::new(Gate {
                tag,
                dim: 4,
                log: log.clone(),
                hold: hold.clone(),
                entered: entered.clone(),
            }),
        )
        .unwrap();
    }
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 8,
        max_wait: Duration::ZERO,
        queue_cap: 512,
        pool: Some(Arc::new(ThreadPool::new(width))),
        tenants: vec![
            ("hot".into(), TenantConfig { weight: w_hot, quota: hot_quota }),
            ("cold".into(), TenantConfig { weight: 1, quota: 0 }),
        ],
        ..EngineConfig::default()
    })
    .unwrap();
    (engine, log, hold, entered)
}

/// Submit one request and spin until the backend reports the batch in
/// flight — from here until the gate opens, the scheduler is frozen.
fn freeze_scheduler(
    engine: &ServingEngine,
    entered: &AtomicBool,
) -> admm_nn::serving::Ticket {
    let warm = engine
        .submit(InferRequest::new("hot", vec![0.5; 4]))
        .expect("warmup submit");
    let t0 = Instant::now();
    while !entered.load(Ordering::SeqCst) {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "warmup batch never reached the backend"
        );
        std::thread::sleep(Duration::from_micros(200));
    }
    warm
}

/// Weighted shares: with tenants at 3:1 and both queues prefilled, the
/// dispatch log must interleave roughly three hot batches per cold
/// batch until the hot queue drains — at every pool width (the DRR
/// ring is scheduler-side state; compute-pool width must not affect
/// the share order).
#[test]
fn weighted_shares_follow_drr_credit_at_every_pool_width() {
    const N: usize = 96;
    for width in [1usize, 2, 4, 8] {
        let (engine, log, hold, entered) = gated_engine(width, 3, 0);
        let warm = freeze_scheduler(&engine, &entered);

        // prefill both queues while the warmup batch blocks dispatch;
        // payloads are unique per ticket so the identity check below
        // also proves no cross-request row mixing
        let mut tickets = Vec::new();
        for i in 0..N {
            let x = vec![1000.0 + i as f32; 4];
            tickets.push((engine.submit(InferRequest::new("hot", x.clone())).unwrap(), x));
        }
        for i in 0..N {
            let x = vec![-(1000.0 + i as f32); 4];
            tickets.push((engine.submit(InferRequest::new("cold", x.clone())).unwrap(), x));
        }
        hold.store(false, Ordering::SeqCst);

        engine.wait(warm).expect("warmup");
        for (t, x) in tickets {
            assert_eq!(engine.wait(t).expect("wait"), x, "width {width}");
        }

        let log = log.lock().unwrap().clone();
        // entry 0 is the warmup batch; everything after is the frozen
        // prefill draining deterministically
        assert_eq!(log[0], ("hot", 1), "width {width}: warmup batch");
        let drain = &log[1..];
        let total_hot: usize =
            drain.iter().filter(|(m, _)| *m == "hot").map(|(_, r)| r).sum();
        let total_cold: usize =
            drain.iter().filter(|(m, _)| *m == "cold").map(|(_, r)| r).sum();
        assert_eq!((total_hot, total_cold), (N, N), "width {width}");

        // the contended region: everything up to the batch that drains
        // the hot queue. Weight 3 vs 1 with quantum = max_batch = 8
        // means hot earns three consecutive 8-row batches per ring
        // cycle against cold's one — so by the time hot's 96 rows are
        // done, cold should have moved ~96/3 = 32 rows (±(one cycle)).
        let last_hot = drain
            .iter()
            .rposition(|(m, _)| *m == "hot")
            .expect("hot batches in log");
        let cold_during: usize = drain[..=last_hot]
            .iter()
            .filter(|(m, _)| *m == "cold")
            .map(|(_, r)| r)
            .sum();
        assert!(
            (16..=40).contains(&cold_during),
            "width {width}: cold moved {cold_during} rows while hot was \
             backlogged; expected ~32 under a 3:1 share (log: {drain:?})"
        );
        assert!(cold_during > 0, "width {width}: cold starved outright");

        // large weights buy *consecutive* batches (the keep-the-floor
        // rule), not just more batches overall
        let mut run = 0usize;
        let mut max_run = 0usize;
        for (m, _) in drain[..=last_hot].iter() {
            if *m == "hot" {
                run += 1;
                max_run = max_run.max(run);
            } else {
                run = 0;
            }
        }
        assert!(
            max_run >= 3,
            "width {width}: longest hot run {max_run}, want >= 3 \
             consecutive batches from weight 3"
        );

        let hot_st = engine.stats("hot").unwrap();
        let cold_st = engine.stats("cold").unwrap();
        assert_eq!(hot_st.completed, (N + 1) as u64, "width {width}");
        assert_eq!(cold_st.completed, N as u64, "width {width}");
    }
}

/// Starvation bound: a 10:1-weighted hot tenant flooding the queue must
/// not starve the cold tenant — every cold request completes within a
/// generous multiple of the weighted-share bound.
#[test]
fn hot_tenant_cannot_starve_cold_under_ten_to_one_load() {
    const HOT_REQS: usize = 120;
    const COLD_REQS: usize = 12;
    for width in [1usize, 2, 4, 8] {
        let mut reg = ModelRegistry::new();
        for tag in ["hot", "cold"] {
            reg.register_named(
                tag.into(),
                Arc::new(DelayEcho {
                    tag,
                    dim: 4,
                    delay: Duration::from_micros(500),
                }),
            )
            .unwrap();
        }
        let engine = ServingEngine::new(reg, EngineConfig {
            max_batch: 8,
            max_wait: Duration::ZERO,
            queue_cap: 256,
            pool: Some(Arc::new(ThreadPool::new(width))),
            tenants: vec![
                ("hot".into(), TenantConfig { weight: 10, quota: 0 }),
                ("cold".into(), TenantConfig { weight: 1, quota: 0 }),
            ],
            ..EngineConfig::default()
        })
        .unwrap();

        let worst_cold = std::thread::scope(|s| {
            let flood = s.spawn(|| {
                let tickets: Vec<_> = (0..HOT_REQS)
                    .map(|i| {
                        engine
                            .submit(InferRequest::new("hot", vec![i as f32; 4]))
                            .expect("hot submit")
                    })
                    .collect();
                for t in tickets {
                    engine.wait(t).expect("hot wait");
                }
            });
            let cold = s.spawn(|| {
                let mut worst = Duration::ZERO;
                for i in 0..COLD_REQS {
                    let t0 = Instant::now();
                    let got = engine
                        .infer_sync(InferRequest::new("cold", vec![-(i as f32); 4]))
                        .expect("cold infer");
                    worst = worst.max(t0.elapsed());
                    assert_eq!(got, vec![-(i as f32); 4]);
                }
                worst
            });
            flood.join().unwrap();
            cold.join().unwrap()
        });

        // weighted-share wait bound: one full ring cycle serves hot up
        // to 10 batches before cold's one, ~5ms of compute — anything
        // within seconds proves cold is being scheduled, not starved
        assert!(
            worst_cold < Duration::from_secs(5),
            "width {width}: worst cold latency {worst_cold:?}"
        );
        assert_eq!(engine.stats("cold").unwrap().completed, COLD_REQS as u64);
        assert_eq!(engine.stats("hot").unwrap().completed, HOT_REQS as u64);
    }
}

/// Per-tenant quota: submits beyond the cap fail with the typed
/// `QuotaExceeded` (not `QueueFull`), other tenants are unaffected,
/// and every admitted ticket still redeems its exact logits.
#[test]
fn quota_rejection_is_typed_and_admitted_tickets_all_redeem() {
    const QUOTA: usize = 4;
    let (engine, _log, hold, entered) = gated_engine(2, 1, QUOTA);
    let warm = freeze_scheduler(&engine, &entered);

    // the warmup request is in flight (not queued), so "hot" has the
    // full quota of queue room left
    let mut admitted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..10 {
        let x = vec![i as f32; 4];
        match engine.submit(InferRequest::new("hot", x.clone())) {
            Ok(t) => admitted.push((t, x)),
            Err(e) => {
                rejected += 1;
                assert_eq!(
                    e,
                    ServingError::QuotaExceeded {
                        model: "hot".into(),
                        quota: QUOTA,
                    },
                    "request {i}"
                );
            }
        }
    }
    assert_eq!(admitted.len(), QUOTA, "first {QUOTA} submits fit the quota");
    assert_eq!(rejected, 10 - QUOTA);

    // the quota is per-model: cold still has the whole queue
    let cold = engine
        .submit(InferRequest::new("cold", vec![7.0; 4]))
        .expect("cold submit under hot's quota pressure");

    hold.store(false, Ordering::SeqCst);
    engine.wait(warm).expect("warmup");
    for (t, x) in admitted {
        assert_eq!(engine.wait(t).expect("admitted ticket"), x);
    }
    assert_eq!(engine.wait(cold).expect("cold ticket"), vec![7.0; 4]);

    let hot_st = engine.stats("hot").unwrap();
    assert_eq!(hot_st.rejected_quota, (10 - QUOTA) as u64);
    assert_eq!(hot_st.submitted, (QUOTA + 1) as u64);
    assert_eq!(hot_st.completed, (QUOTA + 1) as u64);
    let cold_st = engine.stats("cold").unwrap();
    assert_eq!((cold_st.submitted, cold_st.completed, cold_st.rejected_quota), (1, 1, 0));
}

/// Deadline-feasibility admission control: a cold engine admits any
/// deadline (no measurement yet); once the per-row estimate is primed,
/// a deadline the backlog cannot possibly meet is rejected at submit
/// with the typed estimate. With admission control off, the same
/// request is admitted and expires in the queue instead.
#[test]
fn admission_control_rejects_infeasible_deadlines_once_primed() {
    let slow = || {
        Arc::new(DelayEcho {
            tag: "slow",
            dim: 2,
            delay: Duration::from_millis(20),
        })
    };
    let engine_with = |admission: bool| {
        let mut reg = ModelRegistry::new();
        reg.register(slow()).unwrap();
        ServingEngine::new(reg, EngineConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
            queue_cap: 64,
            admission_control: admission,
            ..EngineConfig::default()
        })
        .unwrap()
    };

    let engine = engine_with(true);
    // cold engine: nothing measured yet, so even a deadline-carrying
    // request sails through admission (and completes — the queue is
    // empty, so it dispatches immediately)
    let got = engine
        .infer_sync(
            InferRequest::new("slow", vec![1.0, 2.0])
                .with_deadline(Duration::from_millis(50)),
        )
        .expect("cold engine must not reject on feasibility");
    assert_eq!(got, vec![1.0, 2.0]);

    // that request primed the per-row estimate at ~20ms; with a
    // backlog queued, a 5ms deadline is hopeless and must be rejected
    // at the front door, not left to expire
    let backlog: Vec<_> = (0..5)
        .map(|i| {
            engine
                .submit(InferRequest::new("slow", vec![i as f32, 0.0]))
                .expect("backlog submit")
        })
        .collect();
    let err = engine
        .submit(
            InferRequest::new("slow", vec![9.0, 9.0])
                .with_deadline(Duration::from_millis(5)),
        )
        .expect_err("infeasible deadline must be rejected at submit");
    match err {
        ServingError::DeadlineInfeasible { estimated, deadline } => {
            assert!(
                estimated > deadline,
                "estimate {estimated:?} should exceed deadline {deadline:?}"
            );
            assert_eq!(deadline, Duration::from_millis(5));
        }
        other => panic!("expected DeadlineInfeasible, got {other:?}"),
    }
    assert_eq!(engine.stats("slow").unwrap().rejected_infeasible, 1);
    for t in backlog {
        engine.wait(t).expect("backlog drains normally");
    }

    // same scenario, admission control off: the doomed request is
    // admitted and expires in the queue (the pre-admission-control
    // behavior, still available for offline replay)
    let engine = engine_with(false);
    engine
        .infer_sync(InferRequest::new("slow", vec![1.0, 1.0]))
        .expect("prime");
    let backlog: Vec<_> = (0..2)
        .map(|i| {
            engine
                .submit(InferRequest::new("slow", vec![i as f32, 1.0]))
                .expect("backlog submit")
        })
        .collect();
    let t = engine
        .submit(
            InferRequest::new("slow", vec![9.0, 9.0])
                .with_deadline(Duration::from_millis(1)),
        )
        .expect("admission control off: doomed deadline is admitted");
    assert_eq!(
        engine.wait(t).expect_err("must expire behind the backlog"),
        ServingError::DeadlineExpired
    );
    for t in backlog {
        engine.wait(t).expect("backlog drains normally");
    }
    assert_eq!(engine.stats("slow").unwrap().expired, 1);
}

/// Sharded-condvar regression: 64 threads parked in `wait` (covering
/// all 16 shards several times over) all wake with their own results —
/// no waiter sleeps forever, none steals another's logits. Also covers
/// the late-wait path (result picked up long after completion).
#[test]
fn many_concurrent_waiters_all_wake_through_sharded_condvars() {
    const WAITERS: usize = 64;
    let mut reg = ModelRegistry::new();
    reg.register(Arc::new(DelayEcho {
        tag: "echo",
        dim: 4,
        delay: Duration::from_millis(1),
    }))
    .unwrap();
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 8,
        max_wait: Duration::from_micros(200),
        queue_cap: 256,
        pool: Some(Arc::new(ThreadPool::new(2))),
        ..EngineConfig::default()
    })
    .unwrap();

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..WAITERS)
            .map(|i| {
                let engine = &engine;
                s.spawn(move || {
                    let x = vec![i as f32; 4];
                    let t = engine
                        .submit(InferRequest::new("echo", x.clone()))
                        .expect("submit");
                    assert_eq!(engine.wait(t).expect("wait"), x, "waiter {i}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    assert_eq!(engine.stats("echo").unwrap().completed, WAITERS as u64);

    // late wait: the result must survive until picked up (retention
    // cap is far above one entry)
    let t = engine
        .submit(InferRequest::new("echo", vec![0.25; 4]))
        .unwrap();
    std::thread::sleep(Duration::from_millis(30));
    assert_eq!(engine.wait(t).expect("late wait"), vec![0.25; 4]);
}

/// Package a proxy model without training (structure is what matters).
fn packaged(name: &str, keep: f64, seed: u64) -> (NativeBackend, SparseInfer) {
    let nb = NativeBackend::open_with_batches(name, 8, 8).expect("backend");
    let mut st = TrainState::init(nb.entry(), seed);
    let model = prune_quantize_package(nb.entry(), name, &mut st, keep, 4, 8);
    let sp = SparseInfer::new(&model, nb.entry()).expect("sparse form");
    (nb, sp)
}

/// The fairness layer must not disturb the bit-identical contract:
/// with tenants weighted 3:1 and four submitter threads interleaving
/// two real packaged models, every request's logits stay bit-identical
/// to a serial single-request `SparseInfer` call, at every pool width.
#[test]
fn weighted_tenants_preserve_bit_identical_logits() {
    const THREADS: usize = 4;
    const PER_THREAD: usize = 8;

    let (mlp_nb, mlp_sp) = packaged("mlp", 0.15, 31);
    let (lenet_nb, lenet_sp) = packaged("lenet5", 0.1, 32);
    let mlp_ds = data::for_input_shape(&mlp_nb.entry().input_shape);
    let lenet_ds = data::for_input_shape(&lenet_nb.entry().input_shape);
    let mlp_pool_x = mlp_ds.batch(Split::Test, 0, 32).x;
    let lenet_pool_x = lenet_ds.batch(Split::Test, 0, 32).x;
    let sps = [&mlp_sp, &lenet_sp];
    let xs = [&mlp_pool_x, &lenet_pool_x];
    let names = ["mlp", "lenet5"];

    // skew the mix 3 hot (mlp) : 1 cold (lenet5), matching the weights
    let req_of = |t: usize, i: usize| -> (usize, Vec<f32>) {
        let m = usize::from((t + i) % 4 == 3);
        let dim = sps[m].input_dim();
        let start = ((t * PER_THREAD + i) * 3) % 31;
        (m, xs[m][start * dim..(start + 1) * dim].to_vec())
    };

    let serial = ThreadPool::new(1);
    let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
    for t in 0..THREADS {
        let mut row = Vec::new();
        for i in 0..PER_THREAD {
            let (m, x) = req_of(t, i);
            row.push(sps[m].infer_with(&serial, &x, 1).unwrap());
        }
        want.push(row);
    }

    for width in [1usize, 2, 4, 8] {
        let mut reg = ModelRegistry::new();
        reg.register_named("mlp".into(), Arc::new(packaged("mlp", 0.15, 31).1))
            .unwrap();
        reg.register_named(
            "lenet5".into(),
            Arc::new(packaged("lenet5", 0.1, 32).1),
        )
        .unwrap();
        let engine = ServingEngine::new(reg, EngineConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            pool: Some(Arc::new(ThreadPool::new(width))),
            tenants: vec![
                ("mlp".into(), TenantConfig { weight: 3, quota: 0 }),
                ("lenet5".into(), TenantConfig { weight: 1, quota: 0 }),
            ],
            quantum: 4,
            ..EngineConfig::default()
        })
        .unwrap();

        let got: Vec<Vec<Vec<f32>>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let engine = &engine;
                    let req_of = &req_of;
                    s.spawn(move || {
                        (0..PER_THREAD)
                            .map(|i| {
                                let (m, x) = req_of(t, i);
                                engine
                                    .infer_sync(InferRequest::new(names[m], x))
                                    .expect("infer_sync")
                            })
                            .collect::<Vec<Vec<f32>>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        for t in 0..THREADS {
            for i in 0..PER_THREAD {
                assert_eq!(
                    got[t][i], want[t][i],
                    "width {width}: thread {t} request {i} logits drifted \
                     under weighted scheduling"
                );
            }
        }
        let total: u64 =
            engine.stats_all().iter().map(|(_, s)| s.completed).sum();
        assert_eq!(total, (THREADS * PER_THREAD) as u64);
    }
}
