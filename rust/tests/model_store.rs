//! Integration tests of the versioned model store: publish/list/open
//! round-trips, the opportunistic compression policy, exhaustive
//! corruption sweeps (every truncation and every single-bit flip must
//! come back as a typed `Err`, never a panic), gc's healthy-retention
//! guarantee, lazy per-layer decode isolation, and legacy checkpoint
//! compatibility through the magic-dispatched loader.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::path::PathBuf;

use admm_nn::coordinator::checkpoint::{CompressedLayer, CompressedModel};
use admm_nn::projection::prune_topk;
use admm_nn::quantize::search_interval;
use admm_nn::store::{container, ModelStore};
use admm_nn::tensor::Tensor;
use admm_nn::util::Rng;

/// Fresh per-test store root under the system temp dir.
fn store_root(test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("admm_nn_store_test").join(test);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small random model: two pruned+quantized layers plus a bias.
/// Payload sections are big enough to exercise real entry streams but
/// small enough that exhaustive bit-flip sweeps stay fast.
fn sample_model(seed: u64) -> CompressedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (i, n) in [400usize, 1200].iter().enumerate() {
        let w = prune_topk(&rng.normal_vec(*n, 0.1), n / 8);
        let cfg = search_interval(&w, 3);
        let t = Tensor::new(vec![*n], cfg.apply(&w));
        layers.push(CompressedLayer::from_quantized(&format!("l{i}.w"), &t, &cfg, 4));
    }
    CompressedModel {
        model_name: "toy".into(),
        layers,
        biases: vec![("l0.b".into(), Tensor::new(vec![4], vec![0.5; 4]))],
        accuracy: 0.97,
    }
}

/// A model whose entry stream is extremely regular (constant level at a
/// constant stride), so the LZSS policy is guaranteed to keep it.
fn repetitive_model() -> CompressedModel {
    let n = 10_000usize;
    let mut w = vec![0.0f32; n];
    for i in (0..n).step_by(4) {
        w[i] = 0.5;
    }
    let cfg = search_interval(&w, 3);
    let t = Tensor::new(vec![n], cfg.apply(&w));
    CompressedModel {
        model_name: "regular".into(),
        layers: vec![CompressedLayer::from_quantized("r.w", &t, &cfg, 4)],
        biases: Vec::new(),
        accuracy: 0.5,
    }
}

fn assert_models_bit_equal(a: &CompressedModel, b: &CompressedModel) {
    assert_eq!(a.model_name, b.model_name);
    assert_eq!(a.layers.len(), b.layers.len());
    for (x, y) in a.layers.iter().zip(&b.layers) {
        assert_eq!(x.to_tensor().data(), y.to_tensor().data(), "layer drifted");
        assert_eq!(x.bits, y.bits);
        assert_eq!(x.shape, y.shape);
    }
    assert_eq!(a.biases.len(), b.biases.len());
    for ((xn, xt), (yn, yt)) in a.biases.iter().zip(&b.biases) {
        assert_eq!(xn, yn);
        assert_eq!(xt.data(), yt.data());
    }
    // both container formats store accuracy as f32 (the weights are the
    // bit-exact contract; accuracy is advisory metadata)
    assert!((a.accuracy - b.accuracy).abs() < 1e-6);
}

#[test]
fn publish_assigns_monotonic_versions_and_roundtrips() {
    let store = ModelStore::open_root(store_root("roundtrip")).unwrap();
    let m = sample_model(1);
    let r1 = store.publish(&m).unwrap();
    assert_eq!((r1.name.as_str(), r1.version), ("toy", 1));
    assert!(r1.path.is_file());
    assert_eq!(std::fs::metadata(&r1.path).unwrap().len(), r1.file_bytes);

    let mut m2 = sample_model(2);
    m2.accuracy = 0.98;
    let r2 = store.publish(&m2).unwrap();
    assert_eq!(r2.version, 2);
    assert_eq!(store.list("toy").unwrap(), vec![1, 2]);
    assert_eq!(store.list_models().unwrap(), vec!["toy".to_string()]);

    // no tmp residue from the atomic write path
    let dir = store.root().join("toy");
    for entry in std::fs::read_dir(&dir).unwrap() {
        let name = entry.unwrap().file_name();
        assert!(
            !name.to_string_lossy().starts_with('.'),
            "tmp file left behind: {name:?}"
        );
    }

    // latest by default, explicit versions on request — both bit-exact
    let latest = store.open("toy", None).unwrap();
    assert_eq!(latest.version, 2);
    assert_models_bit_equal(&latest.to_model().unwrap(), &m2);
    let first = store.open("toy", Some(1)).unwrap();
    assert_models_bit_equal(&first.to_model().unwrap(), &m);

    // a store file is also loadable through the plain checkpoint
    // loader (magic dispatch) — one artifact format, two front doors
    let via_ckpt = CompressedModel::load(&r2.path).unwrap();
    assert_models_bit_equal(&via_ckpt, &m2);

    // never-published names list empty, absent versions err typed
    assert!(store.list("ghost").unwrap().is_empty());
    assert!(store.open("ghost", None).is_err());
    assert!(store.open("toy", Some(99)).is_err());
}

#[test]
fn compression_policy_is_threshold_and_savings_gated() {
    let store = ModelStore::open_root(store_root("policy")).unwrap();

    // tiny sections (below COMPRESS_MIN_BYTES) must stay raw
    let mut tiny = sample_model(3);
    tiny.model_name = "tiny".into();
    tiny.layers.truncate(1);
    {
        let w = vec![0.25f32, 0.0, 0.0, -0.25, 0.0, 0.25, 0.0, 0.0];
        let cfg = search_interval(&w, 2);
        let t = Tensor::new(vec![8], cfg.apply(&w));
        tiny.layers[0] = CompressedLayer::from_quantized("t.w", &t, &cfg, 4);
    }
    let r = store.publish(&tiny).unwrap();
    assert_eq!(r.stats.compressed_sections, 0, "{:?}", r.stats);
    assert_eq!(r.stats.stored_payload_bytes, r.stats.raw_payload_bytes);
    assert_models_bit_equal(&store.open("tiny", None).unwrap().to_model().unwrap(), &tiny);

    // a regular entry stream must be kept compressed, and still decode
    // bit-exactly
    let reg = repetitive_model();
    let r = store.publish(&reg).unwrap();
    assert!(r.stats.compressed_sections >= 1, "{:?}", r.stats);
    assert!(
        r.stats.stored_payload_bytes < r.stats.raw_payload_bytes,
        "{:?}",
        r.stats
    );
    assert_models_bit_equal(&store.open("regular", None).unwrap().to_model().unwrap(), &reg);
}

#[test]
fn every_truncation_errs_and_every_bit_flip_errs_without_panic() {
    let bytes = container::encode_model(&sample_model(4)).unwrap();

    // the untouched container decodes
    assert!(container::decode_model(bytes.clone()).is_ok());

    // every prefix truncation is a typed Err
    for len in 0..bytes.len() {
        assert!(
            container::decode_model(bytes[..len].to_vec()).is_err(),
            "truncation at {len}/{} parsed",
            bytes.len()
        );
    }

    // every single-bit flip is caught by a CRC / bounds gate — full
    // decode must return Err (and in particular must not panic)
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut buf = bytes.clone();
            buf[i] ^= 1 << bit;
            assert!(
                container::decode_model(buf).is_err(),
                "bit {bit} of byte {i} flipped but the container decoded"
            );
        }
    }
}

#[test]
fn gc_keeps_newest_healthy_and_corrupt_never_evicts_healthy() {
    let store = ModelStore::open_root(store_root("gc")).unwrap();
    for seed in [1, 2, 3] {
        store.publish(&sample_model(seed)).unwrap();
    }

    // corrupt the NEWEST version on disk (payload byte flip)
    let path = store.path_of("toy", 3);
    let mut bytes = std::fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    assert!(store.open("toy", Some(3)).and_then(|s| s.to_model()).is_err());

    // keep=1: the corrupt v3 must not consume the retention quota —
    // healthy v2 survives, v1 is retired as a plain old version
    let rep = store.gc("toy", 1).unwrap();
    assert_eq!(rep.kept, vec![2]);
    assert_eq!(rep.removed, vec![1]);
    assert_eq!(rep.corrupt_removed, vec![3]);
    assert_eq!(store.list("toy").unwrap(), vec![2]);
    assert!(store.open("toy", None).unwrap().to_model().is_ok());

    // keep larger than what exists keeps everything
    let rep = store.gc("toy", 8).unwrap();
    assert_eq!(rep.kept, vec![2]);
    assert!(rep.removed.is_empty() && rep.corrupt_removed.is_empty());
}

#[test]
fn lazy_decode_isolates_per_layer_corruption() {
    let store = ModelStore::open_root(store_root("lazy")).unwrap();
    let m = sample_model(5);
    let receipt = store.publish(&m).unwrap();

    // flip one byte inside layer 1's payload section only
    let offset = {
        let sv = store.open("toy", None).unwrap();
        assert_eq!(sv.lazy().layers.len(), 2);
        sv.lazy().layers[1].section.offset
    };
    let mut bytes = std::fs::read(&receipt.path).unwrap();
    bytes[offset] ^= 0x01;
    std::fs::write(&receipt.path, &bytes).unwrap();

    // the header still parses and the intact layer still decodes;
    // only the damaged layer (and the eager whole-model path) fail
    let sv = store.open("toy", None).unwrap();
    let l0 = sv.lazy().layer(0).unwrap();
    assert_eq!(l0.to_tensor().data(), m.layers[0].to_tensor().data());
    assert!(sv.lazy().layer(1).is_err());
    assert!(sv.to_model().is_err());
    let (bn, bt) = sv.lazy().bias(0).unwrap();
    assert_eq!((bn.as_str(), bt.data()), ("l0.b", &[0.5f32; 4][..]));
}

#[test]
fn unsafe_model_names_are_refused() {
    let store = ModelStore::open_root(store_root("names")).unwrap();
    for bad in ["", "..", "../evil", "a/b", ".hidden", "sp ace"] {
        let mut m = sample_model(6);
        m.model_name = bad.into();
        assert!(store.publish(&m).is_err(), "published {bad:?}");
        assert!(store.open(bad, None).is_err());
    }
    // names with inner dots/dashes/underscores are fine
    let mut m = sample_model(6);
    m.model_name = "net-v2.5_final".into();
    assert_eq!(store.publish(&m).unwrap().version, 1);
}

#[test]
fn legacy_v1_files_load_through_the_same_front_door() {
    let m = sample_model(7);
    let dir = store_root("legacy");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("legacy.bin");
    std::fs::write(&path, m.to_legacy_bytes().unwrap()).unwrap();
    let loaded = CompressedModel::load(&path).unwrap();
    assert_models_bit_equal(&loaded, &m);

    // and a legacy model republishes into the store unchanged
    let store = ModelStore::open_root(dir.join("store")).unwrap();
    let receipt = store.publish(&loaded).unwrap();
    assert_models_bit_equal(
        &store.open(&receipt.name, None).unwrap().to_model().unwrap(),
        &m,
    );
}
