//! Integration tests over the execution backends.
//!
//! The PJRT half compiles and executes the actual HLO artifacts (the
//! Pallas kernels and the MLP training graph) and cross-validates them
//! against the host-side rust implementations; it requires
//! `make artifacts` and skips cleanly when artifacts are absent. The
//! native half runs the same behavioural contracts (loss decreases,
//! masks freeze, ρ pulls toward Z, eval/infer agree, init is
//! deterministic) on the pure-Rust backend, so the runtime seam is
//! exercised on every checkout — including this offline one.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::backend::native::{model_entry, NativeBackend};
use admm_nn::backend::{Hyper, ModelExec, TrainState};
use admm_nn::data::{self, Dataset, Split};
use admm_nn::projection;
use admm_nn::runtime::Runtime;
use admm_nn::util::Rng;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::load("artifacts").expect("runtime loads"))
}

#[test]
fn manifest_covers_all_models() {
    let Some(rt) = runtime() else { return };
    for m in ["mlp", "lenet5", "alexnet_proxy", "vgg_proxy", "resnet_proxy"] {
        assert!(rt.manifest().models.contains_key(m), "missing {m}");
    }
}

// ---------------------------------------------------------------------
// native backend — always runs
// ---------------------------------------------------------------------

#[test]
fn native_entries_cover_trainable_proxies() {
    for m in ["mlp", "lenet5", "alexnet_proxy", "vgg_proxy", "resnet_proxy"] {
        let e = model_entry(m, 64, 256).expect(m);
        assert!(e.n_weights() > 0, "{m}");
        NativeBackend::from_entry(m, e).expect(m);
    }
}

#[test]
fn native_train_step_decreases_loss_and_respects_masks() {
    let sess = NativeBackend::open_with_batches("mlp", 32, 64).unwrap();
    let ds = data::for_input_shape(&sess.entry().input_shape);
    let mut st = TrainState::init(sess.entry(), 0);

    // prune half of fc1 and freeze the mask
    let wi = TrainState::weight_indices(sess.entry());
    let w0 = &st.params[wi[0]];
    let pruned = projection::prune_topk(w0.data(), w0.len() / 2);
    st.masks[0] = admm_nn::tensor::Tensor::new(
        w0.shape().to_vec(),
        projection::mask_of(&pruned),
    );
    st.params[wi[0]] =
        admm_nn::tensor::Tensor::new(w0.shape().to_vec(), pruned);
    sess.invalidate_slow();

    let hyper = Hyper::default();
    let batch = ds.batch(Split::Train, 0, 32);
    let first = sess.train_step(&mut st, &hyper, &batch).unwrap();
    let mut last = first;
    for i in 1..15 {
        let b = ds.batch(Split::Train, i, 32);
        last = sess.train_step(&mut st, &hyper, &b).unwrap();
    }
    assert!(
        last.loss < first.loss,
        "loss did not decrease: {} -> {}",
        first.loss,
        last.loss
    );
    // masked positions stayed exactly zero through 15 ADAM steps
    let w = &st.params[wi[0]];
    let m = &st.masks[0];
    for (x, mask) in w.data().iter().zip(m.data()) {
        if *mask == 0.0 {
            assert_eq!(*x, 0.0);
        }
    }
}

#[test]
fn native_admm_penalty_pulls_toward_z() {
    let sess = NativeBackend::open_with_batches("mlp", 32, 64).unwrap();
    let ds = data::for_input_shape(&sess.entry().input_shape);
    let hyper = Hyper::default();

    // with huge rho and Z=0, weight norm must shrink faster than with rho=0
    let norm_after = |rho: f32| -> f64 {
        let mut st = TrainState::init(sess.entry(), 0);
        for r in st.rhos.iter_mut() {
            *r = rho;
        }
        sess.invalidate_slow();
        for i in 0..10 {
            let b = ds.batch(Split::Train, i, 32);
            sess.train_step(&mut st, &hyper, &b).unwrap();
        }
        let wi = TrainState::weight_indices(sess.entry());
        wi.iter().map(|&pi| st.params[pi].sq_norm()).sum()
    };
    let with = norm_after(5.0);
    let without = norm_after(0.0);
    assert!(with < without * 0.95, "rho pull missing: {with} vs {without}");
}

#[test]
fn native_eval_and_infer_agree() {
    let sess = NativeBackend::open_with_batches("mlp", 32, 128).unwrap();
    let ds = data::for_input_shape(&sess.entry().input_shape);
    let st = TrainState::init(sess.entry(), 7);

    // batch-64 infer logits must produce the same #correct as evaluate
    let eval_b = sess.entry().eval_batch;
    let batch = ds.batch(Split::Test, 0, eval_b);
    let eval = sess.evaluate(&st, ds.as_ref(), 1).unwrap();

    let mut correct = 0u64;
    let b64 = 64;
    for chunk in 0..(eval_b / b64) {
        let xs = &batch.x[chunk * b64 * 784..(chunk + 1) * b64 * 784];
        let logits = sess.infer(&st, xs, b64).unwrap();
        for i in 0..b64 {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            if pred == batch.y[chunk * b64 + i] {
                correct += 1;
            }
        }
    }
    assert_eq!(correct as f64, eval.correct, "eval/infer disagree");
}

#[test]
fn native_train_state_init_is_deterministic() {
    let entry = model_entry("mlp", 64, 256).unwrap();
    let a = TrainState::init(&entry, 42);
    let b = TrainState::init(&entry, 42);
    let c = TrainState::init(&entry, 43);
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.data(), y.data());
    }
    assert_ne!(a.params[0].data(), c.params[0].data());
}

// ---------------------------------------------------------------------
// PJRT artifacts — skip without `make artifacts`
// ---------------------------------------------------------------------

#[test]
fn prune_artifact_matches_host_projection() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    // mlp fc3.w is the smallest proj artifact (1000 elements)
    let v = rng.normal_vec(1000, 1.0);
    for k in [0usize, 1, 100, 999, 1000] {
        let kernel = rt.prune(&v, k).expect("prune artifact runs");
        let host = projection::prune_topk(&v, k);
        // identical nonzero pattern and values (ties are measure-zero
        // for gaussian data)
        for (a, b) in kernel.iter().zip(&host) {
            assert!((a - b).abs() < 1e-6, "k={k}: {a} vs {b}");
        }
    }
}

#[test]
fn quant_artifact_matches_host_projection() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let mut v = rng.normal_vec(1000, 0.5);
    for i in (0..1000).step_by(3) {
        v[i] = 0.0; // pruned positions must stay zero
    }
    for (q, hm) in [(0.1f32, 4u32), (0.05, 8), (0.25, 2)] {
        let kernel = rt.quant(&v, q, hm).expect("quant artifact runs");
        let host = projection::quant_nearest(&v, q, hm);
        for (a, b) in kernel.iter().zip(&host) {
            assert!((a - b).abs() < 1e-6, "q={q} hm={hm}: {a} vs {b}");
        }
    }
}

#[test]
fn quant_err_artifact_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let v = rng.normal_vec(1000, 0.5);
    for q in [0.05f32, 0.2, 0.7] {
        let kernel = rt.quant_err(&v, q, 4).expect("qerr artifact runs");
        let host = projection::quant_error(&v, q, 4);
        assert!(
            (kernel - host).abs() < 1e-3 * (1.0 + host),
            "q={q}: {kernel} vs {host}"
        );
    }
}

#[test]
fn train_step_decreases_loss_and_respects_masks() {
    let Some(rt) = runtime() else { return };
    let sess = rt.model("mlp").expect("mlp session");
    let ds = data::for_input_shape(&sess.entry.input_shape);
    let mut st = TrainState::init(&sess.entry, 0);

    // prune half of fc1 and freeze the mask
    let wi = TrainState::weight_indices(&sess.entry);
    let w0 = &st.params[wi[0]];
    let pruned = projection::prune_topk(w0.data(), w0.len() / 2);
    st.masks[0] = admm_nn::tensor::Tensor::new(
        w0.shape().to_vec(),
        projection::mask_of(&pruned),
    );
    st.params[wi[0]] =
        admm_nn::tensor::Tensor::new(w0.shape().to_vec(), pruned);
    sess.invalidate_slow();

    let hyper = Hyper::default();
    let batch = ds.batch(Split::Train, 0, sess.entry.train_batch);
    let first = sess.train_step(&mut st, &hyper, &batch).unwrap();
    let mut last = first;
    for i in 1..15 {
        let b = ds.batch(Split::Train, i, sess.entry.train_batch);
        last = sess.train_step(&mut st, &hyper, &b).unwrap();
    }
    assert!(
        last.loss < first.loss,
        "loss did not decrease: {} -> {}",
        first.loss,
        last.loss
    );
    // masked positions stayed exactly zero through 15 ADAM steps
    let w = &st.params[wi[0]];
    let m = &st.masks[0];
    for (x, mask) in w.data().iter().zip(m.data()) {
        if *mask == 0.0 {
            assert_eq!(*x, 0.0);
        }
    }
}

#[test]
fn admm_penalty_pulls_toward_z() {
    let Some(rt) = runtime() else { return };
    let sess = rt.model("mlp").expect("mlp session");
    let ds = data::for_input_shape(&sess.entry.input_shape);
    let hyper = Hyper::default();

    // with huge rho and Z=0, weight norm must shrink faster than with rho=0
    let norm_after = |rho: f32| -> f64 {
        let mut st = TrainState::init(&sess.entry, 0);
        for r in st.rhos.iter_mut() {
            *r = rho;
        }
        sess.invalidate_slow();
        for i in 0..10 {
            let b = ds.batch(Split::Train, i, sess.entry.train_batch);
            sess.train_step(&mut st, &hyper, &b).unwrap();
        }
        let wi = TrainState::weight_indices(&sess.entry);
        wi.iter().map(|&pi| st.params[pi].sq_norm()).sum()
    };
    let with = norm_after(5.0);
    let without = norm_after(0.0);
    assert!(with < without * 0.95, "rho pull missing: {with} vs {without}");
}

#[test]
fn eval_and_infer_agree() {
    let Some(rt) = runtime() else { return };
    let sess = rt.model("mlp").expect("mlp session");
    let ds = data::for_input_shape(&sess.entry.input_shape);
    let st = TrainState::init(&sess.entry, 7);

    // infer_b64 logits must produce the same #correct as the eval artifact
    let batch = ds.batch(Split::Test, 0, sess.entry.eval_batch);
    let eval = sess.evaluate(&st, ds.as_ref(), 1).unwrap();

    let mut correct = 0u64;
    let b64 = 64;
    for chunk in 0..(sess.entry.eval_batch / b64) {
        let xs = &batch.x[chunk * b64 * 784..(chunk + 1) * b64 * 784];
        let logits = sess.infer(&st, xs, b64).unwrap();
        for i in 0..b64 {
            let row = &logits[i * 10..(i + 1) * 10];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            if pred == batch.y[chunk * b64 + i] {
                correct += 1;
            }
        }
    }
    assert_eq!(correct as f64, eval.correct, "eval/infer disagree");
}

#[test]
fn train_state_init_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let entry = rt.manifest().model("mlp").unwrap();
    let a = TrainState::init(entry, 42);
    let b = TrainState::init(entry, 42);
    let c = TrainState::init(entry, 43);
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.data(), y.data());
    }
    assert_ne!(a.params[0].data(), c.params[0].data());
}
