//! Vendored std-only shim of the `anyhow` API surface this workspace
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros, and the
//! [`Context`] extension trait. The build is offline (no registry), so
//! the real crate cannot be fetched; this implements the same contract —
//! a type-erased error with a human-readable context chain.
//!
//! Display follows upstream: `{e}` prints the outermost message, `{e:#}`
//! prints the whole chain separated by `: `.

use std::fmt;

/// Type-erased error: a message plus an optional cause chain.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), cause: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Self {
        Error { msg: ctx.to_string(), cause: Some(Box::new(self)) }
    }

    /// Iterate the chain outermost-first.
    fn chain(&self) -> impl Iterator<Item = &str> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let e = next?;
            next = e.cause.as_deref();
            Some(e.msg.as_str())
        })
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            let mut first = true;
            for m in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut rest = self.cause.as_deref();
        if rest.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = rest {
            write!(f, "\n    {}", e.msg)?;
            rest = e.cause.as_deref();
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion
// coherent (no overlap with the reflexive `From<Error> for Error`).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain: Vec<String> = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Error { msg, cause: err.map(Box::new) });
        }
        err.expect("chain is non-empty")
    }
}

/// `Result` with the shimmed [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and convert them to [`Error`]) — the same
/// extension upstream provides on `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_chain_display() {
        let e = io_err().context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn with_context_and_macros() {
        let e: Error = anyhow!("bad value {}", 3);
        assert_eq!(e.to_string(), "bad value 3");
        fn inner() -> Result<()> {
            bail!("boom {x}", x = 1);
        }
        let e = inner().with_context(|| "outer".to_string()).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: boom 1");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn run() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert_eq!(run().unwrap_err().to_string(), "gone");
    }
}
