//! Vendored typed stub of the PJRT/XLA API used by `admm_nn::runtime`.
//!
//! The build is offline and the real PJRT plugin is not present in this
//! environment, so this crate provides the exact type/method surface the
//! runtime compiles against. [`Literal`] is fully functional host-side
//! (it is plain data); everything that would execute on a device —
//! [`PjRtClient::cpu`], compilation, execution — returns an
//! "unavailable" error. `Runtime::load` therefore fails fast with a
//! clear message, and all artifact-dependent tests/benches skip, which
//! is the behaviour they already implement for missing artifacts.

use std::path::Path;

/// Stub error: carries a message; call-sites format it with `{:?}`.
#[derive(Clone, Debug)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend unavailable (offline stub build — see \
         rust/vendor/xla)"
    ))
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>, dims: Vec<i64>) -> Literal;
    fn unwrap(lit: &Literal) -> Result<Vec<Self>>;
}

/// Host-side literal: typed flat data + dimensions (or a tuple).
#[derive(Clone, Debug)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal::F32 { data, dims }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>, dims: Vec<i64>) -> Literal {
        Literal::I32 { data, dims }
    }
    fn unwrap(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(Error(format!("literal is not i32: {other:?}"))),
        }
    }
}

impl Literal {
    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal::F32 { data: vec![v], dims: vec![] }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::wrap(v.to_vec(), vec![v.len() as i64])
    }

    fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(parts) => parts.len(),
        }
    }

    /// Reinterpret with new dimensions of identical element count.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape to {dims:?}: have {} elements",
                self.element_count()
            )));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 { data, dims: dims.to_vec() },
            Literal::I32 { data, .. } => Literal::I32 { data, dims: dims.to_vec() },
            t @ Literal::Tuple(_) => t,
        })
    }

    /// Copy out the flat data.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(self)
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(self)?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts),
            other => Err(Error(format!("not a tuple literal: {other:?}"))),
        }
    }
}

/// Parsed HLO module (stub: never constructed successfully off-line).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable(&format!(
            "parsing HLO {}",
            path.as_ref().display()
        )))
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("buffer readback"))
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// PJRT client (stub: construction fails with a clear message).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32_i32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(l.to_vec::<i32>().is_err());
        let l = Literal::vec1(&[1i32, -2]).reshape(&[2, 1]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, -2]);
        assert_eq!(Literal::scalar(7.5).get_first_element::<f32>().unwrap(), 7.5);
    }

    #[test]
    fn reshape_checks_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e:?}").contains("unavailable"));
    }
}
