//! End-to-end PJRT benchmarks: the per-step costs of the coordinator's
//! request path (train step, eval, inference, projection artifacts).
//!
//! This is the bench behind EXPERIMENTS.md §Perf — it separates the
//! PJRT execute time from the literal-marshalling overhead so L3 tuning
//! is measurable.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench admm_step`
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::coordinator::{TrainConfig, Trainer};
use admm_nn::data::{self, Split};
use admm_nn::runtime::{Hyper, Runtime, TrainState};
use admm_nn::util::bench::{bench, black_box};
use admm_nn::util::Rng;

fn main() -> admm_nn::Result<()> {
    let rt = Runtime::load("artifacts")?;
    println!("platform: {}\n", rt.platform());

    for model in ["mlp", "lenet5", "alexnet_proxy"] {
        println!("== {model} ==");
        let sess = rt.model(model)?;
        let ds = data::for_input_shape(&sess.entry.input_shape);
        let mut st = TrainState::init(&sess.entry, 0);
        let hyper = Hyper::default();
        let b = sess.entry.train_batch;
        let batch = ds.batch(Split::Train, 0, b);

        // warm the executable caches (compile once)
        sess.train_step(&mut st, &hyper, &batch)?;
        let r = bench(&format!("{model} train_step (B={b})"), 2, 12, || {
            sess.train_step(&mut st, &hyper, &batch).unwrap();
        });
        println!(
            "    -> {:.1} samples/s",
            b as f64 / r.median_s
        );

        bench(&format!("{model} eval batch (B={})", sess.entry.eval_batch),
              1, 8, || {
            black_box(sess.evaluate(&st, ds.as_ref(), 1).unwrap());
        });

        let x1 = ds.batch(Split::Test, 0, 1);
        sess.infer(&st, &x1.x, 1)?;
        let r1 = bench(&format!("{model} infer B=1 (latency)"), 3, 20, || {
            black_box(sess.infer(&st, &x1.x, 1).unwrap());
        });
        let x64 = ds.batch(Split::Test, 0, 64);
        let r64 = bench(&format!("{model} infer B=64 (throughput)"), 3, 20, || {
            black_box(sess.infer(&st, &x64.x, 64).unwrap());
        });
        println!(
            "    -> latency {:.2}ms, throughput {:.0} samples/s",
            r1.median_s * 1e3,
            64.0 / r64.median_s
        );
        println!();
    }

    println!("== projection artifacts (Pallas kernels via PJRT) ==");
    let mut rng = Rng::new(7);
    for n in [25_000usize, 400_000] {
        let v = rng.normal_vec(n, 0.1);
        rt.prune(&v, n / 20)?; // warm compile
        bench(&format!("proj_prune artifact n={n}"), 2, 10, || {
            black_box(rt.prune(black_box(&v), n / 20).unwrap());
        });
        rt.quant(&v, 0.02, 4.0 as u32 as f32 as u32)?;
        bench(&format!("proj_quant artifact n={n}"), 2, 10, || {
            black_box(rt.quant(black_box(&v), 0.02, 4).unwrap());
        });
    }

    println!("\n== coordinator loop overhead (10-step run) ==");
    let sess = rt.model("mlp")?;
    let ds = data::for_input_shape(&sess.entry.input_shape);
    let mut st = TrainState::init(&sess.entry, 1);
    let mut trainer = Trainer::new(&sess, ds.as_ref());
    bench("mlp 10-step training run", 1, 5, || {
        trainer
            .run(&mut st, &TrainConfig { steps: 10, ..Default::default() })
            .unwrap();
    });
    Ok(())
}
