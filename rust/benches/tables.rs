//! Table/figure regeneration harness: prints every table and figure of
//! the paper's evaluation (the same rows/series), and times each
//! generator. `cargo bench --bench tables` is the one-command
//! reproduction of the analytic half of the evaluation; measured rows
//! appear automatically once the examples have written `results/*.json`.
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::time::Instant;

use admm_nn::hwmodel::HwConfig;
use admm_nn::report::{self, MeasuredRun};

fn main() {
    let runs = MeasuredRun::load_all(std::path::Path::new("results"));
    if runs.is_empty() {
        println!(
            "(no measured runs in results/ — run the examples to add \
             measured rows)\n"
        );
    } else {
        println!("({} measured runs loaded from results/)\n", runs.len());
    }
    let hw = HwConfig::default();

    let blocks: Vec<(&str, Box<dyn Fn() -> String>)> = vec![
        ("Table 1", Box::new(|| report::table_pruning("lenet5", &runs))),
        ("Table 2", Box::new(|| report::table_pruning("alexnet", &runs))),
        ("Table 3", Box::new(|| report::table_pruning("vgg16", &runs))),
        ("Table 4", Box::new(|| report::table_pruning("resnet50", &runs))),
        ("Table 5", Box::new(|| report::table_model_size("lenet5", &runs))),
        ("Table 6", Box::new(|| report::table_model_size("alexnet", &runs))),
        ("Table 7", Box::new(|| report::table7(&runs))),
        ("Table 8", Box::new(report::table8)),
        ("Table 9", Box::new(move || report::table9(&hw))),
        ("Fig 4", Box::new(move || report::fig4(&hw))),
        ("§4.3 on-chip", Box::new(report::onchip)),
    ];

    for (name, gen) in &blocks {
        let t0 = Instant::now();
        let text = gen();
        let dt = t0.elapsed().as_secs_f64();
        println!("################ {name}  (generated in {:.1}ms)", dt * 1e3);
        println!("{text}");
    }
}
