//! Micro-benchmarks of the L3 hot paths (no PJRT needed).
//!
//! These are the operations the coordinator runs between train steps —
//! projections, quantizer search, sparse encoding, the hardware model —
//! sized at real layer shapes (LeNet-5 fc1 = 400K, AlexNet fc1 = 37.7M
//! scaled to 1M for iteration count sanity).
//!
//! Every path converted by the projection-engine PR is measured
//! before/after in the same process: the seed's allocating / exact
//! implementation vs the zero-alloc / histogram one, with the speedup
//! printed per pair. Pass `--json` (or set `BENCH_JSON`) to also write
//! `BENCH_hot_paths.json` with all medians and speedup ratios.
//!
//! Run: `cargo bench --bench hot_paths [-- --json]`
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use admm_nn::hwmodel::HwConfig;
use admm_nn::projection::{self, ProjectionWorkspace};
use admm_nn::quantize;
use admm_nn::sparsity::{Csr, RelIndex};
use admm_nn::util::bench::{black_box, BenchSuite};
use admm_nn::util::{Rng, ThreadPool};

/// PR-1's per-call scoped-spawn fan-out, reproduced verbatim as the
/// "before" side of the persistent-pool comparison (spawn + join per
/// call, ~10µs per worker).
fn scoped_spawn_map<T, R, S>(
    workers: usize,
    items: Vec<T>,
    scratch: &mut Vec<S>,
    mut mk: impl FnMut() -> S,
    f: impl Fn(usize, T, &mut S) -> R + Sync,
) -> Vec<R>
where
    T: Send,
    R: Send,
    S: Send,
{
    let n_items = items.len();
    let workers = workers.min(n_items).max(1);
    while scratch.len() < workers {
        scratch.push(mk());
    }
    if workers == 1 {
        let s0 = &mut scratch[0];
        return items
            .into_iter()
            .enumerate()
            .map(|(i, t)| f(i, t, &mut *s0))
            .collect();
    }
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let mut collected: Vec<Vec<(usize, R)>> = Vec::new();
    std::thread::scope(|sc| {
        let mut handles = Vec::with_capacity(workers);
        for s in scratch.iter_mut().take(workers) {
            let slots = &slots;
            let next = &next;
            let f = &f;
            handles.push(sc.spawn(move || {
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= slots.len() {
                        break;
                    }
                    let item = slots[i].lock().unwrap().take().unwrap();
                    local.push((i, f(i, item, &mut *s)));
                }
                local
            }));
        }
        for h in handles {
            collected.push(h.join().unwrap());
        }
    });
    let mut out: Vec<Option<R>> = (0..n_items).map(|_| None).collect();
    for batch in collected {
        for (i, r) in batch {
            out[i] = Some(r);
        }
    }
    out.into_iter().map(|o| o.unwrap()).collect()
}

fn main() {
    // `make bench-serving` runs just the serving section into its own
    // BENCH_serving.json (the full run keeps the serving cases inside
    // BENCH_hot_paths.json, where bench-report diffs them).
    if std::env::var("BENCH_ONLY").ok().as_deref() == Some("serving") {
        let mut suite = BenchSuite::new("serving");
        serving_benches(&mut suite);
        suite.finish();
        return;
    }
    // `make bench-gemm` runs just the packed-GEMM section into its own
    // BENCH_gemm.json (proxy-shape kernels + serving throughput at
    // queue depth 64).
    if std::env::var("BENCH_ONLY").ok().as_deref() == Some("gemm") {
        let mut suite = BenchSuite::new("gemm");
        gemm_benches(&mut suite);
        suite.finish();
        return;
    }
    // `make bench-store` runs just the model-store section into its own
    // BENCH_store.json (publish, eager vs lazy open, hot-swap latency).
    if std::env::var("BENCH_ONLY").ok().as_deref() == Some("store") {
        let mut suite = BenchSuite::new("store");
        store_benches(&mut suite);
        suite.finish();
        return;
    }
    // `make bench-train` runs just the sharded train/eval width sweep
    // into its own BENCH_train.json (train_step + evaluate at pinned
    // pool widths {1, 2, 4, 8} on the lenet5 / resnet_proxy shapes).
    if std::env::var("BENCH_ONLY").ok().as_deref() == Some("train") {
        let mut suite = BenchSuite::new("train");
        train_benches(&mut suite);
        suite.finish();
        return;
    }
    let mut suite = BenchSuite::new("hot_paths");
    println!("== L3 hot paths ==");
    let mut rng = Rng::new(42);
    let pool = ThreadPool::global();
    println!("(thread pool: {} workers)", pool.threads());

    // -- prune_topk: allocating vs zero-alloc vs blocked select ------------
    let mut ws = ProjectionWorkspace::new();
    let mut idxsel_scratch: Vec<u32> = Vec::new();
    for n in [25_000usize, 400_000, 1_000_000] {
        let v = rng.normal_vec(n, 0.1);
        let k = n / 20;
        let alloc = suite.bench(&format!("prune_topk n={n} k=5% (alloc)"), 3, 15, || {
            black_box(projection::prune_topk(black_box(&v), k));
        });
        let idxsel = suite.bench(
            &format!("prune_topk n={n} k=5% (index select, PR-1)"),
            3,
            15,
            || {
                projection::prune_topk_into_indexsel(
                    black_box(&v), k, &mut idxsel_scratch, &mut ws.out);
                black_box(ws.out.len());
            },
        );
        let into = suite.bench(
            &format!("prune_topk n={n} k=5% (blocked select)"),
            3,
            15,
            || {
                projection::prune_topk_into(black_box(&v), k, &mut ws.mags, &mut ws.out);
                black_box(ws.out.len());
            },
        );
        // PR-3 parallel partition select vs the single-lane blocked
        // select just measured (LeNet fc1 = 400K, AlexNet-fc1-ish = 1M;
        // the 25K case sits below the split grain and stays ~1x). Width
        // comes from the global pool (ADMM_NN_THREADS).
        let par = suite.bench(
            &format!("prune_topk n={n} k=5% (parallel blocked select)"),
            3,
            15,
            || {
                projection::prune_topk_into_par(
                    pool, black_box(&v), k, &mut ws.mags, &mut ws.out);
                black_box(ws.out.len());
            },
        );
        suite.speedup(&format!("prune_topk n={n}"), &alloc, &into);
        suite.speedup(&format!("prune_topk n={n} blocked vs index select"), &idxsel, &into);
        suite.speedup(&format!("topk select n={n} parallel partition"), &into, &par);
    }

    let v400k = rng.normal_vec(400_000, 0.1);
    suite.bench("prune_threshold n=400K", 3, 15, || {
        black_box(projection::prune_threshold(black_box(&v400k), 20_000));
    });

    // -- quant_nearest: allocating vs zero-alloc vs zero-alloc+parallel ----
    let pruned = projection::prune_topk(&v400k, 20_000);
    let q_alloc = suite.bench("quant_nearest n=400K 3b (alloc)", 3, 15, || {
        black_box(projection::quant_nearest(black_box(&pruned), 0.02, 4));
    });
    let q_into = suite.bench("quant_nearest n=400K 3b (into)", 3, 15, || {
        projection::quant_nearest_into(black_box(&pruned), 0.02, 4, &mut ws.out);
        black_box(ws.out.len());
    });
    suite.speedup("quant_nearest n=400K (zero-alloc)", &q_alloc, &q_into);
    // the path Constraint::project_with actually runs for Levels
    let mut qout = vec![0.0f32; pruned.len()];
    let q_par = suite.bench("quant_nearest n=400K 3b (into+par)", 3, 15, || {
        projection::quant_nearest_into_par(pool, black_box(&pruned), 0.02, 4, &mut qout);
        black_box(qout.len());
    });
    suite.speedup("quant_nearest n=400K", &q_alloc, &q_par);

    suite.bench("quant_error n=400K", 3, 15, || {
        black_box(projection::quant_error(black_box(&pruned), 0.02, 4));
    });

    // -- quantizer search: exact (seed) vs histogram -----------------------
    let s_exact = suite.bench("search_interval n=400K (exact, 80xO(n))", 1, 5, || {
        black_box(quantize::search_interval_exact(black_box(&pruned), 3));
    });
    let s_hist = suite.bench("search_interval n=400K (histogram)", 1, 9, || {
        black_box(quantize::search_interval(black_box(&pruned), 3));
    });
    suite.speedup("search_interval n=400K", &s_exact, &s_hist);

    let b_exact = suite.bench("select_bits n=400K tol 2e-2 (exact)", 0, 3, || {
        black_box(quantize::select_bits_exact(black_box(&pruned), 2e-2, 8));
    });
    let b_hist = suite.bench("select_bits n=400K tol 2e-2 (histogram)", 1, 9, || {
        black_box(quantize::select_bits(black_box(&pruned), 2e-2, 8));
    });
    suite.speedup("select_bits n=400K", &b_exact, &b_hist);

    println!("\n== sparse encoding ==");
    let cfg = quantize::search_interval(&pruned, 3);
    let codes = quantize::encode_levels(&cfg.apply(&pruned), &cfg);
    let e_alloc = suite.bench("RelIndex::encode n=400K 5% (alloc)", 3, 15, || {
        black_box(RelIndex::encode(black_box(&codes), 8));
    });
    let mut enc_reuse = RelIndex::new(8);
    let e_into = suite.bench("RelIndex::encode n=400K 5% (into)", 3, 15, || {
        enc_reuse.encode_into(black_box(&codes));
        black_box(enc_reuse.stored_entries());
    });
    suite.speedup("RelIndex::encode n=400K", &e_alloc, &e_into);
    let enc = RelIndex::encode(&codes, 8);
    suite.bench("RelIndex::decode n=400K", 3, 15, || {
        black_box(enc.decode());
    });
    suite.bench("Csr::encode 800x500 (5% dense)", 3, 15, || {
        black_box(Csr::encode(black_box(&codes), 800, 500));
    });

    // parallel RelIndex packaging: encode every layer of a model, the
    // CompressedModel packaging stage (serial per layer in PR-1)
    let pkg_layers: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let w = projection::prune_topk(&rng.normal_vec(150_000 + 30_000 * i, 0.1),
                                           (150_000 + 30_000 * i) / 20);
            let c = quantize::search_interval(&w, 3);
            quantize::encode_levels(&c.apply(&w), &c)
        })
        .collect();
    let pkg_sizes: Vec<usize> = pkg_layers.iter().map(|l| l.len()).collect();
    let p_serial = suite.bench("RelIndex packaging 6 layers (serial)", 3, 15, || {
        for c in &pkg_layers {
            black_box(RelIndex::encode(black_box(c), 8).stored_entries());
        }
    });
    let p_par = suite.bench("RelIndex packaging 6 layers (parallel)", 3, 15, || {
        let encs = pool.map_with_scratch_sized(
            (0..pkg_layers.len()).collect::<Vec<usize>>(),
            &pkg_sizes,
            &mut Vec::new(),
            || (),
            |_, i, _| RelIndex::encode(&pkg_layers[i], 8).stored_entries(),
        );
        black_box(encs.len());
    });
    suite.speedup("RelIndex packaging 6 layers", &p_serial, &p_par);

    println!("\n== thread pool ==");
    // LeNet-scale per-layer fan-out over small layers: the work per call
    // is small enough that PR-1's per-call scoped spawn/join overhead
    // (~10µs per worker) was measurable; the persistent pool replaces it
    // with a queue push + condvar wake. This case must not regress.
    let small_layers: Vec<Vec<f32>> =
        (0..8).map(|i| rng.normal_vec(4_000 + 512 * i, 0.1)).collect();
    let small_keep: Vec<usize> = small_layers.iter().map(|l| l.len() / 10).collect();
    let mut spawn_wss: Vec<ProjectionWorkspace> = Vec::new();
    let fan_spawn = suite.bench("fanout 8 small layers (scoped spawn, PR-1)", 10, 50, || {
        let nnz = scoped_spawn_map(
            pool.threads(),
            (0..small_layers.len()).collect::<Vec<usize>>(),
            &mut spawn_wss,
            ProjectionWorkspace::new,
            |_, i, w| {
                projection::prune_topk_into(
                    &small_layers[i], small_keep[i], &mut w.mags, &mut w.out);
                w.out.iter().filter(|&&x| x != 0.0).count()
            },
        );
        black_box(nnz.len());
    });
    let mut pool_wss: Vec<ProjectionWorkspace> = Vec::new();
    let small_sizes: Vec<usize> = small_layers.iter().map(|l| l.len()).collect();
    let fan_pool = suite.bench("fanout 8 small layers (persistent pool)", 10, 50, || {
        let nnz = pool.map_with_scratch_sized(
            (0..small_layers.len()).collect::<Vec<usize>>(),
            &small_sizes,
            &mut pool_wss,
            ProjectionWorkspace::new,
            |_, i, w| {
                projection::prune_topk_into(
                    &small_layers[i], small_keep[i], &mut w.mags, &mut w.out);
                w.out.iter().filter(|&&x| x != 0.0).count()
            },
        );
        black_box(nnz.len());
    });
    suite.speedup("fanout 8 small layers (spawn overhead)", &fan_spawn, &fan_pool);

    // dominant-layer fan-out: one 1M fc among tiny siblings. PR-1 ran
    // the big layer's elementwise work inline on its single worker
    // (nested calls never split), idling every other core; the
    // size-aware schedule lets the quant projection split across them.
    let mut dom_layers: Vec<Vec<f32>> = vec![rng.normal_vec(1_000_000, 0.1)];
    for _ in 0..7 {
        dom_layers.push(rng.normal_vec(2_000, 0.1));
    }
    let dom_sizes: Vec<usize> = dom_layers.iter().map(|l| l.len()).collect();
    let mut dom_out: Vec<Vec<f32>> =
        dom_layers.iter().map(|l| vec![0.0f32; l.len()]).collect();
    let dom_inline = {
        let dom_layers = &dom_layers;
        let mut bufs = std::mem::take(&mut dom_out);
        let r = suite.bench("dominant-layer fanout (inline nested, PR-1)", 3, 15, || {
            let done = scoped_spawn_map(
                pool.threads(),
                bufs.drain(..).enumerate().collect::<Vec<(usize, Vec<f32>)>>(),
                &mut Vec::new(),
                || (),
                |_, (i, mut buf), _| {
                    projection::quant_nearest_into(&dom_layers[i], 0.02, 4, &mut buf);
                    buf
                },
            );
            bufs = done;
            black_box(bufs.len());
        });
        dom_out = bufs;
        r
    };
    let dom_split = {
        let dom_layers = &dom_layers;
        let mut bufs = std::mem::take(&mut dom_out);
        let r = suite.bench("dominant-layer fanout (size-aware split)", 3, 15, || {
            let done = pool.map_with_scratch_sized(
                bufs.drain(..).enumerate().collect::<Vec<(usize, Vec<f32>)>>(),
                &dom_sizes,
                &mut Vec::new(),
                || (),
                |_, (i, mut buf), _| {
                    projection::quant_nearest_into_par(
                        pool, &dom_layers[i], 0.02, 4, &mut buf);
                    buf
                },
            );
            bufs = done;
            black_box(bufs.len());
        });
        dom_out = bufs;
        r
    };
    black_box(dom_out.len());
    suite.speedup("dominant-layer fanout (size-aware)", &dom_inline, &dom_split);

    println!("\n== hardware model ==");
    let hw = HwConfig::default();
    suite.bench("speedup() single point", 10, 50, || {
        black_box(hw.speedup(black_box(0.2)));
    });
    suite.bench("break_even_portion (60 bisections)", 5, 30, || {
        black_box(hw.break_even_portion());
    });
    let portions: Vec<f64> = (1..=90).map(|i| i as f64 / 100.0).collect();
    suite.bench("fig4 sweep (90 points)", 5, 30, || {
        black_box(hw.sweep(black_box(&portions)));
    });

    println!("\n== dual update (tensor ops) ==");
    use admm_nn::tensor::Tensor;
    let w = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    let z = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    // seed path as the ADMM loop actually ran it: two temporaries plus a
    // separate residual pass
    let mut u_seed = Tensor::zeros(vec![400_000]);
    let d_seed = suite.bench("dual update U+=W-Z +resid (seed, alloc)", 3, 20, || {
        u_seed.add_assign(&w.sub(&z));
        black_box(w.sub(&z).sq_norm());
    });
    let mut u_fused = Tensor::zeros(vec![400_000]);
    let d_fused = suite.bench("dual update U+=W-Z +resid (fused)", 3, 20, || {
        black_box(u_fused.dual_update(&w, &z));
    });
    suite.speedup("dual_update n=400K", &d_seed, &d_fused);

    println!("\n== sparse serving vs dense (hwmodel cross-check) ==");
    // Serve the MLP proxy from its stored CompressedModel form (RelIndex
    // → CSR, levels on the fly) vs dense masked inference on the native
    // backend, and put the measured host speedup next to the analytic
    // accelerator prediction for the same keep ratio. The host CPU has
    // no index-decode hardware, so measured < modeled is expected — the
    // point is that both now exist on the same axis.
    {
        use admm_nn::backend::native::NativeBackend;
        use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
        use admm_nn::backend::{ModelExec, TrainState};
        use admm_nn::data::{self, Dataset, Split};

        let nb = NativeBackend::open("mlp").expect("native backend");
        let ds = data::for_input_shape(&nb.entry().input_shape);
        let batch = ds.batch(Split::Test, 0, 64);
        for keep in [0.2f64, 0.05] {
            let mut st = TrainState::init(nb.entry(), 9);
            let model = prune_quantize_package(nb.entry(), "mlp", &mut st, keep, 4, 8);
            let sp = SparseInfer::new(&model, nb.entry()).expect("sparse server");
            let dense = suite.bench(
                &format!("mlp dense masked infer b=64 keep={keep}"),
                3,
                15,
                || {
                    black_box(nb.infer(&st, &batch.x, 64).unwrap().len());
                },
            );
            let sparse = suite.bench(
                &format!("mlp sparse CSR infer b=64 keep={keep}"),
                3,
                15,
                || {
                    black_box(sp.infer_with(pool, &batch.x, 64).unwrap().len());
                },
            );
            suite.speedup(
                &format!("sparse serving keep={keep} (measured host)"),
                &dense,
                &sparse,
            );
            println!(
                "    hwmodel prediction at keep={keep}: {:.2}x \
                 (fixed-area accelerator, Fig. 4 curve)",
                hw.speedup(keep)
            );
        }
    }

    gemm_benches(&mut suite);
    serving_benches(&mut suite);
    store_benches(&mut suite);
    train_benches(&mut suite);

    suite.finish();
}

/// Data-parallel sharded training: `train_step` and `evaluate`
/// throughput at pinned pool widths {1, 2, 4, 8} on the lenet5 and
/// resnet_proxy shapes, with the speedup of every width over the
/// width-1 serial fallback. Results are bit-identical across widths
/// (the `train_shard` property suite pins that); these cases price the
/// batch-sharded fan-out + fixed-order reduction. Width 1 is the old
/// single-lane cost — the acceptance bar is >1.5x on `train_step` at
/// width 4.
fn train_benches(suite: &mut BenchSuite) {
    use admm_nn::backend::native::NativeBackend;
    use admm_nn::backend::{Hyper, ModelExec, TrainState};
    use admm_nn::data::{self, Dataset, Split};

    println!("\n== sharded train/eval (pool width sweep) ==");
    let cases: [(&str, usize, usize, usize); 2] =
        [("lenet5", 32, 2, 10), ("resnet_proxy", 16, 1, 5)];
    for (name, bsz, warmup, iters) in cases {
        let mut base_train = None;
        let mut base_eval = None;
        for width in [1usize, 2, 4, 8] {
            let nb = NativeBackend::open_with_batches(name, bsz, bsz)
                .expect("native backend")
                .with_pool(ThreadPool::new(width));
            let ds = data::for_input_shape(&nb.entry().input_shape);
            let mut st = TrainState::init(nb.entry(), 5);
            let hyper = Hyper::default();
            let batch = ds.batch(Split::Train, 0, bsz);
            let tr = suite.bench(
                &format!("train_step {name} b={bsz} width={width}"),
                warmup,
                iters,
                || {
                    black_box(nb.train_step(&mut st, &hyper, &batch).unwrap().loss);
                },
            );
            let ev = suite.bench(
                &format!("evaluate {name} b={bsz} width={width}"),
                warmup,
                iters,
                || {
                    black_box(nb.evaluate(&st, &*ds, 1).unwrap().correct);
                },
            );
            if let (Some(bt), Some(be)) = (&base_train, &base_eval) {
                suite.speedup(
                    &format!("train_step {name} b={bsz} width {width} vs 1"),
                    bt,
                    &tr,
                );
                suite.speedup(
                    &format!("evaluate {name} b={bsz} width {width} vs 1"),
                    be,
                    &ev,
                );
            } else {
                base_train = Some(tr);
                base_eval = Some(ev);
            }
        }
    }
}

/// Packed cache-blocked GEMM vs the naive reference at the proxy-model
/// hot shapes, serial and fanned out on the global pool, plus the fused
/// bias+ReLU epilogue vs the unfused two-pass form and a batched
/// serving-throughput case at queue depth 64. The three (m, k, n)
/// cases are the shapes the train/serving loops actually run: the
/// lenet5 conv2 im2col GEMM, the alexnet_proxy fc1 dense layer, and
/// the resnet_proxy strided 1×1 projection shortcut.
fn gemm_benches(suite: &mut BenchSuite) {
    use admm_nn::tensor::{self, Epilogue};

    println!("\n== packed GEMM (naive ref vs cache-blocked microkernel) ==");
    let mut rng = Rng::new(7);
    let pool = ThreadPool::global();
    let cases: [(&str, usize, usize, usize); 3] = [
        ("lenet5 conv2 im2col", 4096, 500, 50),
        ("alexnet_proxy fc1", 64, 768, 384),
        ("resnet_proxy 1x1 shortcut", 16384, 16, 32),
    ];
    for (label, m, k, n) in cases {
        let a = rng.normal_vec(m * k, 0.1);
        let b = rng.normal_vec(k * n, 0.1);
        let mut out = vec![0.0f32; m * n];
        let naive = suite.bench(
            &format!("gemm {label} {m}x{k}x{n} (naive ref)"),
            1,
            5,
            || {
                tensor::gemm_ref(black_box(&a), black_box(&b), m, k, n, &mut out);
                black_box(out[0]);
            },
        );
        let packed = suite.bench(
            &format!("gemm {label} {m}x{k}x{n} (packed)"),
            1,
            5,
            || {
                tensor::gemm(black_box(&a), black_box(&b), m, k, n, &mut out);
                black_box(out[0]);
            },
        );
        let packed_par = suite.bench(
            &format!("gemm {label} {m}x{k}x{n} (packed+par)"),
            1,
            5,
            || {
                tensor::gemm_par(pool, black_box(&a), black_box(&b), m, k, n, &mut out);
                black_box(out[0]);
            },
        );
        suite.speedup(&format!("gemm {label} packed vs naive"), &naive, &packed);
        suite.speedup(&format!("gemm {label} pool fan-out"), &packed, &packed_par);
    }

    // fused bias+ReLU epilogue vs the two-pass form the backends used
    // to run (GEMM, then separate bias and clamp sweeps over out)
    {
        let (m, k, n) = (4096usize, 500usize, 50usize);
        let a = rng.normal_vec(m * k, 0.1);
        let b = rng.normal_vec(k * n, 0.1);
        let bias = rng.normal_vec(n, 0.1);
        let mut out = vec![0.0f32; m * n];
        let two_pass = suite.bench(
            &format!("gemm+bias+relu {m}x{k}x{n} (two-pass)"),
            1,
            5,
            || {
                tensor::gemm(black_box(&a), black_box(&b), m, k, n, &mut out);
                for row in out.chunks_mut(n) {
                    for (v, &bv) in row.iter_mut().zip(&bias) {
                        *v += bv;
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                black_box(out[0]);
            },
        );
        let fused = suite.bench(
            &format!("gemm+bias+relu {m}x{k}x{n} (fused epilogue)"),
            1,
            5,
            || {
                tensor::gemm_epi(
                    black_box(&a),
                    black_box(&b),
                    m,
                    k,
                    n,
                    Epilogue::BiasRelu(&bias),
                    &mut out,
                );
                black_box(out[0]);
            },
        );
        suite.speedup(&format!("gemm epilogue fusion {m}x{k}x{n}"), &two_pass, &fused);
    }

    // serving throughput at queue depth 64: 64 queued single-row
    // requests coalesced into one batched sparse pass (the workspace
    // arena and packed kernels sit under this path)
    {
        use admm_nn::backend::native::NativeBackend;
        use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
        use admm_nn::backend::TrainState;
        use admm_nn::data::{self, Dataset, Split};
        use admm_nn::serving::{
            EngineConfig, InferRequest, ModelRegistry, ServingEngine,
        };
        use std::sync::Arc;
        use std::time::Duration;

        let nb = NativeBackend::open("mlp").expect("native backend");
        let mut st = TrainState::init(nb.entry(), 13);
        let model = prune_quantize_package(nb.entry(), "mlp", &mut st, 0.05, 4, 8);
        let sp: Arc<SparseInfer> =
            Arc::new(SparseInfer::new(&model, nb.entry()).expect("sparse form"));
        let ds = data::for_input_shape(&nb.entry().input_shape);
        let dim = sp.input_dim();
        let batch = ds.batch(Split::Test, 0, 64);
        let rows: Vec<Vec<f32>> = (0..64)
            .map(|i| batch.x[i * dim..(i + 1) * dim].to_vec())
            .collect();
        let mut reg = ModelRegistry::new();
        reg.register_named("mlp".into(), sp.clone()).unwrap();
        let engine = ServingEngine::new(reg, EngineConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 512,
            ..EngineConfig::default()
        })
        .unwrap();
        suite.bench("serving batched dispatch depth=64 (gemm suite)", 3, 15, || {
            let tickets: Vec<_> = rows
                .iter()
                .map(|r| {
                    engine
                        .submit(InferRequest::new("mlp", r.clone()))
                        .expect("submit")
                })
                .collect();
            let mut total = 0usize;
            for t in tickets {
                total += engine.wait(t).expect("wait").len();
            }
            black_box(total);
        });
        for (name, stats) in engine.stats_all() {
            println!("    gemm-suite engine [{name}]: {}", stats.summary());
        }
    }
}

/// Serving-engine throughput: micro-batched dispatch vs single-request
/// dispatch (`max_batch = 1`) through the same `ServingEngine` API, at
/// queue depths {1, 8, 64}. Depth 1 cannot coalesce — the batched
/// engine still holds its 200µs batching window, so expect <1x there
/// (that row prices the window, not a regression); the win grows with
/// depth, and at 64 the batched engine runs one fanned-out sparse pass
/// where single-request dispatch pays 64 scheduler round trips and 64
/// narrow passes.
fn serving_benches(suite: &mut BenchSuite) {
    use admm_nn::backend::native::NativeBackend;
    use admm_nn::backend::sparse_infer::{prune_quantize_package, SparseInfer};
    use admm_nn::backend::TrainState;
    use admm_nn::data::{self, Dataset, Split};
    use admm_nn::serving::{
        EngineConfig, InferRequest, ModelRegistry, ServingEngine,
    };
    use std::sync::Arc;
    use std::time::Duration;

    println!("\n== serving engine (batched vs single-request dispatch) ==");
    let nb = NativeBackend::open("mlp").expect("native backend");
    let mut st = TrainState::init(nb.entry(), 13);
    let model = prune_quantize_package(nb.entry(), "mlp", &mut st, 0.05, 4, 8);
    let sp: Arc<SparseInfer> =
        Arc::new(SparseInfer::new(&model, nb.entry()).expect("sparse form"));
    let ds = data::for_input_shape(&nb.entry().input_shape);
    let dim = sp.input_dim();
    let batch = ds.batch(Split::Test, 0, 64);
    let rows: Vec<Vec<f32>> = (0..64)
        .map(|i| batch.x[i * dim..(i + 1) * dim].to_vec())
        .collect();

    let engine_with = |mb: usize| {
        let mut reg = ModelRegistry::new();
        reg.register_named("mlp".into(), sp.clone()).unwrap();
        ServingEngine::new(reg, EngineConfig {
            max_batch: mb,
            max_wait: Duration::from_micros(200),
            queue_cap: 512,
            ..EngineConfig::default()
        })
        .unwrap()
    };
    let single = engine_with(1);
    let batched = engine_with(64);

    for depth in [1usize, 8, 64] {
        let run = |engine: &ServingEngine| {
            let tickets: Vec<_> = (0..depth)
                .map(|i| {
                    engine
                        .submit(InferRequest::new("mlp", rows[i].clone()))
                        .expect("submit")
                })
                .collect();
            let mut n = 0usize;
            for t in tickets {
                n += engine.wait(t).expect("wait").len();
            }
            black_box(n);
        };
        let s = suite.bench(
            &format!("serving single-request dispatch depth={depth}"),
            3,
            15,
            || run(&single),
        );
        let b = suite.bench(
            &format!("serving batched dispatch depth={depth}"),
            3,
            15,
            || run(&batched),
        );
        suite.speedup(&format!("serving micro-batching depth={depth}"), &s, &b);
    }
    for (name, stats) in batched.stats_all() {
        println!("    batched engine [{name}]: {}", stats.summary());
    }

    // two-tenant weighted fair share: one engine serving the same
    // sparse model under two names with 3:1 weights, 64 mixed requests
    // per wave — prices the deficit-round-robin pick loop (per-queue
    // credit accounting, ring rotation) against the single-tenant
    // dispatch above, and its stats line shows the p50/p99 percentiles
    {
        use admm_nn::serving::TenantConfig;
        let mut reg = ModelRegistry::new();
        reg.register_named("hot".into(), sp.clone()).unwrap();
        reg.register_named("cold".into(), sp.clone()).unwrap();
        let engine = ServingEngine::new(reg, EngineConfig {
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_cap: 512,
            tenants: vec![
                ("hot".into(), TenantConfig { weight: 3, quota: 0 }),
                ("cold".into(), TenantConfig { weight: 1, quota: 0 }),
            ],
            ..EngineConfig::default()
        })
        .unwrap();
        suite.bench(
            "serving weighted 2-tenant dispatch depth=64 (3:1)",
            3,
            15,
            || {
                let tickets: Vec<_> = (0..64)
                    .map(|i| {
                        let name = if i % 4 == 3 { "cold" } else { "hot" };
                        engine
                            .submit(InferRequest::new(name, rows[i].clone()))
                            .expect("submit")
                    })
                    .collect();
                let mut n = 0usize;
                for t in tickets {
                    n += engine.wait(t).expect("wait").len();
                }
                black_box(n);
            },
        );
        for (name, stats) in engine.stats_all() {
            println!("    weighted engine [{name}]: {}", stats.summary());
        }
    }
}

/// Versioned model store: publish cost (encode + atomic write), eager
/// vs lazy open (the lazy header parse is what serving pays before it
/// decides which layers to decode), and hot-swap control-plane latency
/// while ~64+ requests sit queued against the swapped model — the
/// zero-downtime claim priced, not just tested.
fn store_benches(suite: &mut BenchSuite) {
    use admm_nn::backend::native::NativeBackend;
    use admm_nn::backend::sparse_infer::prune_quantize_package;
    use admm_nn::backend::TrainState;
    use admm_nn::serving::{
        EngineConfig, InferBackend, InferRequest, ModelRegistry, ServingEngine,
    };
    use admm_nn::store::ModelStore;
    use std::sync::Arc;
    use std::time::Duration;

    println!("\n== versioned model store ==");
    let nb = NativeBackend::open("mlp").expect("native backend");
    let mut st = TrainState::init(nb.entry(), 13);
    let model = prune_quantize_package(nb.entry(), "mlp", &mut st, 0.05, 4, 8);

    let root = std::env::temp_dir()
        .join(format!("admm_nn_store_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let store = ModelStore::open_root(&root).expect("store root");
    let receipt = store.publish(&model).expect("seed publish");
    println!(
        "    container: {} bytes, {} of {} sections compressed \
         (payload {} -> {})",
        receipt.file_bytes,
        receipt.stats.compressed_sections,
        receipt.stats.total_sections,
        receipt.stats.raw_payload_bytes,
        receipt.stats.stored_payload_bytes,
    );

    suite.bench("store publish (encode + atomic write)", 2, 10, || {
        black_box(store.publish(&model).expect("publish").version);
    });
    let eager = suite.bench("store open eager (full decode)", 2, 10, || {
        let sv = store.open("mlp", Some(1)).expect("open");
        black_box(sv.to_model().expect("decode").layers.len());
    });
    let lazy = suite.bench("store open lazy (header only)", 2, 10, || {
        let sv = store.open("mlp", Some(1)).expect("open");
        black_box(sv.lazy().layers.len());
    });
    suite.speedup("store lazy vs eager open", &eager, &lazy);
    let _ = std::fs::remove_dir_all(&root);

    // hot-swap latency with a deep queue: a slow backend keeps ~64+
    // requests outstanding for the whole measurement, so every swap and
    // rollback pays the real cost — COW snapshot + drain accounting
    // under a contended queue lock
    struct Pinned {
        dim: usize,
        delay: Duration,
    }
    impl InferBackend for Pinned {
        fn name(&self) -> &str {
            "pinned"
        }
        fn input_dim(&self) -> usize {
            self.dim
        }
        fn n_classes(&self) -> usize {
            self.dim
        }
        fn infer_batch(
            &self,
            _pool: &ThreadPool,
            x: &[f32],
            _bsz: usize,
        ) -> admm_nn::Result<Vec<f32>> {
            std::thread::sleep(self.delay);
            Ok(x.to_vec())
        }
    }
    let mk = || -> Arc<dyn InferBackend> {
        Arc::new(Pinned { dim: 16, delay: Duration::from_millis(2) })
    };
    let mut reg = ModelRegistry::new();
    reg.register_versioned("pinned".into(), mk(), Some(1)).unwrap();
    let engine = ServingEngine::new(reg, EngineConfig {
        max_batch: 8,
        max_wait: Duration::ZERO,
        queue_cap: 8192,
        ..EngineConfig::default()
    })
    .unwrap();
    for _ in 0..2048 {
        let _ = engine.submit(InferRequest::new("pinned", vec![0.5f32; 16]));
    }
    let swapped = mk();
    suite.bench("hot swap + rollback (queue depth 64+)", 2, 10, || {
        black_box(
            engine
                .swap_model("pinned", swapped.clone(), Some(2))
                .expect("swap"),
        );
        black_box(engine.rollback("pinned").expect("rollback"));
    });
    for (name, stats) in engine.stats_all() {
        println!("    [{name}] {}", stats.summary());
    }
}
