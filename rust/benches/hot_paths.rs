//! Micro-benchmarks of the L3 hot paths (no PJRT needed).
//!
//! These are the operations the coordinator runs between train steps —
//! projections, quantizer search, sparse encoding, the hardware model —
//! sized at real layer shapes (LeNet-5 fc1 = 400K, AlexNet fc1 = 37.7M
//! scaled to 1M for iteration count sanity).
//!
//! Every path converted by the projection-engine PR is measured
//! before/after in the same process: the seed's allocating / exact
//! implementation vs the zero-alloc / histogram one, with the speedup
//! printed per pair. Pass `--json` (or set `BENCH_JSON`) to also write
//! `BENCH_hot_paths.json` with all medians and speedup ratios.
//!
//! Run: `cargo bench --bench hot_paths [-- --json]`

use admm_nn::hwmodel::HwConfig;
use admm_nn::projection::{self, ProjectionWorkspace};
use admm_nn::quantize;
use admm_nn::sparsity::{Csr, RelIndex};
use admm_nn::util::bench::{black_box, BenchSuite};
use admm_nn::util::{Rng, ThreadPool};

fn main() {
    let mut suite = BenchSuite::new("hot_paths");
    println!("== L3 hot paths ==");
    let mut rng = Rng::new(42);
    let pool = ThreadPool::global();
    println!("(thread pool: {} workers)", pool.threads());

    // -- prune_topk: allocating vs zero-alloc ------------------------------
    let mut ws = ProjectionWorkspace::new();
    for n in [25_000usize, 400_000, 1_000_000] {
        let v = rng.normal_vec(n, 0.1);
        let k = n / 20;
        let alloc = suite.bench(&format!("prune_topk n={n} k=5% (alloc)"), 3, 15, || {
            black_box(projection::prune_topk(black_box(&v), k));
        });
        let into = suite.bench(&format!("prune_topk n={n} k=5% (into)"), 3, 15, || {
            projection::prune_topk_into(black_box(&v), k, &mut ws.idx, &mut ws.out);
            black_box(ws.out.len());
        });
        suite.speedup(&format!("prune_topk n={n}"), &alloc, &into);
    }

    let v400k = rng.normal_vec(400_000, 0.1);
    suite.bench("prune_threshold n=400K", 3, 15, || {
        black_box(projection::prune_threshold(black_box(&v400k), 20_000));
    });

    // -- quant_nearest: allocating vs zero-alloc vs zero-alloc+parallel ----
    let pruned = projection::prune_topk(&v400k, 20_000);
    let q_alloc = suite.bench("quant_nearest n=400K 3b (alloc)", 3, 15, || {
        black_box(projection::quant_nearest(black_box(&pruned), 0.02, 4));
    });
    let q_into = suite.bench("quant_nearest n=400K 3b (into)", 3, 15, || {
        projection::quant_nearest_into(black_box(&pruned), 0.02, 4, &mut ws.out);
        black_box(ws.out.len());
    });
    suite.speedup("quant_nearest n=400K (zero-alloc)", &q_alloc, &q_into);
    // the path Constraint::project_with actually runs for Levels
    let mut qout = vec![0.0f32; pruned.len()];
    let q_par = suite.bench("quant_nearest n=400K 3b (into+par)", 3, 15, || {
        projection::quant_nearest_into_par(pool, black_box(&pruned), 0.02, 4, &mut qout);
        black_box(qout.len());
    });
    suite.speedup("quant_nearest n=400K", &q_alloc, &q_par);

    suite.bench("quant_error n=400K", 3, 15, || {
        black_box(projection::quant_error(black_box(&pruned), 0.02, 4));
    });

    // -- quantizer search: exact (seed) vs histogram -----------------------
    let s_exact = suite.bench("search_interval n=400K (exact, 80xO(n))", 1, 5, || {
        black_box(quantize::search_interval_exact(black_box(&pruned), 3));
    });
    let s_hist = suite.bench("search_interval n=400K (histogram)", 1, 9, || {
        black_box(quantize::search_interval(black_box(&pruned), 3));
    });
    suite.speedup("search_interval n=400K", &s_exact, &s_hist);

    let b_exact = suite.bench("select_bits n=400K tol 2e-2 (exact)", 0, 3, || {
        black_box(quantize::select_bits_exact(black_box(&pruned), 2e-2, 8));
    });
    let b_hist = suite.bench("select_bits n=400K tol 2e-2 (histogram)", 1, 9, || {
        black_box(quantize::select_bits(black_box(&pruned), 2e-2, 8));
    });
    suite.speedup("select_bits n=400K", &b_exact, &b_hist);

    println!("\n== sparse encoding ==");
    let cfg = quantize::search_interval(&pruned, 3);
    let codes = quantize::encode_levels(&cfg.apply(&pruned), &cfg);
    let e_alloc = suite.bench("RelIndex::encode n=400K 5% (alloc)", 3, 15, || {
        black_box(RelIndex::encode(black_box(&codes), 8));
    });
    let mut enc_reuse = RelIndex::new(8);
    let e_into = suite.bench("RelIndex::encode n=400K 5% (into)", 3, 15, || {
        enc_reuse.encode_into(black_box(&codes));
        black_box(enc_reuse.stored_entries());
    });
    suite.speedup("RelIndex::encode n=400K", &e_alloc, &e_into);
    let enc = RelIndex::encode(&codes, 8);
    suite.bench("RelIndex::decode n=400K", 3, 15, || {
        black_box(enc.decode());
    });
    suite.bench("Csr::encode 800x500 (5% dense)", 3, 15, || {
        black_box(Csr::encode(black_box(&codes), 800, 500));
    });

    println!("\n== hardware model ==");
    let hw = HwConfig::default();
    suite.bench("speedup() single point", 10, 50, || {
        black_box(hw.speedup(black_box(0.2)));
    });
    suite.bench("break_even_portion (60 bisections)", 5, 30, || {
        black_box(hw.break_even_portion());
    });
    let portions: Vec<f64> = (1..=90).map(|i| i as f64 / 100.0).collect();
    suite.bench("fig4 sweep (90 points)", 5, 30, || {
        black_box(hw.sweep(black_box(&portions)));
    });

    println!("\n== dual update (tensor ops) ==");
    use admm_nn::tensor::Tensor;
    let w = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    let z = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    // seed path as the ADMM loop actually ran it: two temporaries plus a
    // separate residual pass
    let mut u_seed = Tensor::zeros(vec![400_000]);
    let d_seed = suite.bench("dual update U+=W-Z +resid (seed, alloc)", 3, 20, || {
        u_seed.add_assign(&w.sub(&z));
        black_box(w.sub(&z).sq_norm());
    });
    let mut u_fused = Tensor::zeros(vec![400_000]);
    let d_fused = suite.bench("dual update U+=W-Z +resid (fused)", 3, 20, || {
        black_box(u_fused.dual_update(&w, &z));
    });
    suite.speedup("dual_update n=400K", &d_seed, &d_fused);

    suite.finish();
}
