//! Micro-benchmarks of the L3 hot paths (no PJRT needed).
//!
//! These are the operations the coordinator runs between train steps —
//! projections, quantizer search, sparse encoding, the hardware model —
//! sized at real layer shapes (LeNet-5 fc1 = 400K, AlexNet fc1 = 37.7M
//! scaled to 1M for iteration count sanity).
//!
//! Run: `cargo bench --bench hot_paths`

use admm_nn::hwmodel::HwConfig;
use admm_nn::projection;
use admm_nn::quantize;
use admm_nn::sparsity::{Csr, RelIndex};
use admm_nn::util::bench::{bench, black_box};
use admm_nn::util::Rng;

fn main() {
    println!("== L3 hot paths ==");
    let mut rng = Rng::new(42);

    for n in [25_000usize, 400_000, 1_000_000] {
        let v = rng.normal_vec(n, 0.1);
        let k = n / 20;
        bench(&format!("prune_topk n={n} k=5%"), 3, 15, || {
            black_box(projection::prune_topk(black_box(&v), k));
        });
    }

    let v400k = rng.normal_vec(400_000, 0.1);
    bench("prune_threshold n=400K", 3, 15, || {
        black_box(projection::prune_threshold(black_box(&v400k), 20_000));
    });

    let pruned = projection::prune_topk(&v400k, 20_000);
    bench("quant_nearest n=400K (3 bits)", 3, 15, || {
        black_box(projection::quant_nearest(black_box(&pruned), 0.02, 4));
    });
    bench("quant_error n=400K", 3, 15, || {
        black_box(projection::quant_error(black_box(&pruned), 0.02, 4));
    });
    bench("search_interval n=400K (golden, 80 iters)", 1, 5, || {
        black_box(quantize::search_interval(black_box(&pruned), 3));
    });
    bench("select_bits n=400K (tol 2e-2)", 1, 5, || {
        black_box(quantize::select_bits(black_box(&pruned), 2e-2, 8));
    });

    println!("\n== sparse encoding ==");
    let cfg = quantize::search_interval(&pruned, 3);
    let codes = quantize::encode_levels(&cfg.apply(&pruned), &cfg);
    bench("RelIndex::encode n=400K (5% dense)", 3, 15, || {
        black_box(RelIndex::encode(black_box(&codes), 8));
    });
    let enc = RelIndex::encode(&codes, 8);
    bench("RelIndex::decode n=400K", 3, 15, || {
        black_box(enc.decode());
    });
    bench("Csr::encode 800x500 (5% dense)", 3, 15, || {
        black_box(Csr::encode(black_box(&codes), 800, 500));
    });

    println!("\n== hardware model ==");
    let hw = HwConfig::default();
    bench("speedup() single point", 10, 50, || {
        black_box(hw.speedup(black_box(0.2)));
    });
    bench("break_even_portion (60 bisections)", 5, 30, || {
        black_box(hw.break_even_portion());
    });
    let portions: Vec<f64> = (1..=90).map(|i| i as f64 / 100.0).collect();
    bench("fig4 sweep (90 points)", 5, 30, || {
        black_box(hw.sweep(black_box(&portions)));
    });

    println!("\n== dual update (tensor ops) ==");
    use admm_nn::tensor::Tensor;
    let w = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    let z = Tensor::new(vec![400_000], rng.normal_vec(400_000, 0.1));
    let mut u = Tensor::zeros(vec![400_000]);
    bench("dual update U += W - Z (400K)", 3, 20, || {
        u.add_assign(&w.sub(&z));
    });
}
