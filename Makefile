# One entry point for builder and reviewer alike.
#
#   make verify  — the tier-1 gate: release build + full test suite
#   make bench   — hot-path microbenchmarks with machine-readable output
#                  (writes BENCH_hot_paths.json into the repo root)

.PHONY: verify bench

verify:
	cargo build --release && cargo test -q

bench:
	cargo bench --bench hot_paths -- --json
