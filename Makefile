# One entry point for builder and reviewer alike.
#
#   make verify       — the tier-1 gate (release build + full test
#                       suite), then the offline end-to-end native
#                       pipeline test again in release mode and the
#                       quickstart example (dense train → ADMM prune →
#                       quantize → sparse serving), so every merge
#                       proves the whole workflow actually executes
#   make bench        — hot-path microbenchmarks with machine-readable
#                       output (writes BENCH_hot_paths.json into the
#                       repo root; includes the serving-engine cases)
#   make bench-serving— just the serving-engine throughput cases
#                       (batched vs single-request dispatch at queue
#                       depths 1/8/64), written to BENCH_serving.json
#   make bench-gemm   — just the packed-GEMM cases (proxy-shape
#                       kernels, fused epilogue, serving throughput at
#                       queue depth 64), written to BENCH_gemm.json
#   make bench-store  — just the versioned-model-store cases (publish,
#                       eager vs lazy open, hot-swap latency under a
#                       deep queue), written to BENCH_store.json
#   make bench-soak   — the deterministic soak harness (all four load
#                       profiles at pool widths 1 and 4 against a
#                       two-tenant 3:1 weighted engine, invariants
#                       scored), written to BENCH_soak.json with
#                       p50/p99 latency per profile
#   make bench-train  — just the sharded train/eval width sweep
#                       (train_step + evaluate at pool widths 1/2/4/8
#                       on lenet5 and resnet_proxy shapes, speedups vs
#                       width 1), written to BENCH_train.json
#   make bench-report — run the benchmarks, then diff the fresh
#                       BENCH_hot_paths.json against the committed
#                       BENCH_baseline.json, printing per-path speedup
#                       ratios. The first toolchain run seeds the empty
#                       baseline and commits it (the trajectory anchor);
#                       later runs never touch the committed file.
#   make lint         — repo-invariant static analysis (`repo-lint`)
#                       over rust/src/**: unsafe discipline, zero-alloc
#                       hot paths, panic-free load paths, spawn/lock
#                       hygiene, hash-iteration determinism. Fails the
#                       build on any unannotated violation; see
#                       rust/src/analysis/mod.rs for the rules and the
#                       `lint:allow(<rule-id>) <why>` annotation policy.
#   make miri         — run the pool/arena unit tests under miri
#                       (nightly-only; skips with a note when the
#                       toolchain is absent)
#   make tsan         — run the serving/pool tests under ThreadSanitizer
#                       (nightly-only; skips with a note when absent)

.PHONY: verify lint miri tsan bench bench-serving bench-gemm bench-store bench-soak bench-train bench-report

# Style allowances now live as crate-level #![allow] attributes in each
# crate root (rust/src/lib.rs documents why); everything else is -D.
CLIPPY_LINTS = -D warnings

verify: lint
	cargo build --release && cargo test -q
	cargo clippy --all-targets -- $(CLIPPY_LINTS)
	cargo test --release -q -p admm_nn --test integration_pipeline
	cargo run --release -p admm_nn --example quickstart

lint:
	cargo run --release -p admm_nn --bin repo-lint -- rust/src

# Nightly-gated soundness passes. Both skip gracefully (exit 0 with a
# note) when no nightly toolchain is installed, so they are safe to
# wire into CI as best-effort jobs. Scope: the unsafe surface (the
# thread pool's lifetime-erasure transmute) and its neighbors — the
# full suite under miri would take hours.
miri:
	@if rustup toolchain list 2>/dev/null | grep -q nightly; then \
		rustup run nightly cargo miri test -p admm_nn --lib util:: \
		|| exit 1; \
	else \
		echo "miri: no nightly toolchain installed — skipping (rustup toolchain install nightly && rustup component add miri --toolchain nightly)"; \
	fi

tsan:
	@if rustup toolchain list 2>/dev/null | grep -q nightly; then \
		RUSTFLAGS="-Z sanitizer=thread" rustup run nightly cargo test \
			-p admm_nn --lib util:: -Z build-std \
			--target x86_64-unknown-linux-gnu \
		|| exit 1; \
	else \
		echo "tsan: no nightly toolchain installed — skipping (rustup toolchain install nightly)"; \
	fi

# Cargo runs bench binaries with CWD = the package root (rust/), so pin
# the JSON output to the repo root where bench-report expects it.
bench:
	BENCH_JSON_DIR=$(CURDIR) cargo bench --bench hot_paths -- --json

bench-serving:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=serving cargo bench --bench hot_paths -- --json

bench-gemm:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=gemm cargo bench --bench hot_paths -- --json

bench-store:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=store cargo bench --bench hot_paths -- --json

bench-soak:
	BENCH_JSON_DIR=$(CURDIR) cargo run --release -p admm_nn -- soak --profile all --widths 1,4 --json

bench-train:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=train cargo bench --bench hot_paths -- --json

bench-report: bench
	@cp BENCH_baseline.json .bench_baseline.before 2>/dev/null || true
	cargo run --release -p admm_nn --bin bench-report -- BENCH_hot_paths.json BENCH_baseline.json
	@# Auto-commit ONLY a genuine first seeding: the pre-run baseline was
	@# the empty placeholder ("results":[]) and the tool filled it in. A
	@# hand-edited or otherwise-diverged baseline is never touched, and a
	@# failed commit (e.g. no git identity) only prints a note.
	@if grep -q '"results":\[\]' .bench_baseline.before 2>/dev/null \
	   && ! cmp -s BENCH_baseline.json .bench_baseline.before; then \
		git add BENCH_baseline.json && \
		git commit -q -m "Seed benchmark baseline from first toolchain run" -- BENCH_baseline.json \
		&& echo "committed seeded BENCH_baseline.json" \
		|| echo "note: baseline seeded but not committed (commit it manually)"; \
	fi; rm -f .bench_baseline.before
