# One entry point for builder and reviewer alike.
#
#   make verify       — the tier-1 gate: release build + full test suite
#   make bench        — hot-path microbenchmarks with machine-readable
#                       output (writes BENCH_hot_paths.json into the
#                       repo root)
#   make bench-report — run the benchmarks, then diff the fresh
#                       BENCH_hot_paths.json against the committed
#                       BENCH_baseline.json, printing per-path speedup
#                       ratios (first ever run seeds the baseline;
#                       commit the seeded file to start the trajectory)

.PHONY: verify bench bench-report

verify:
	cargo build --release && cargo test -q

# Cargo runs bench binaries with CWD = the package root (rust/), so pin
# the JSON output to the repo root where bench-report expects it.
bench:
	BENCH_JSON_DIR=$(CURDIR) cargo bench --bench hot_paths -- --json

bench-report: bench
	cargo run --release -p admm_nn --bin bench-report -- BENCH_hot_paths.json BENCH_baseline.json
