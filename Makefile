# One entry point for builder and reviewer alike.
#
#   make verify       — the tier-1 gate (release build + full test
#                       suite), then the offline end-to-end native
#                       pipeline test again in release mode and the
#                       quickstart example (dense train → ADMM prune →
#                       quantize → sparse serving), so every merge
#                       proves the whole workflow actually executes
#   make bench        — hot-path microbenchmarks with machine-readable
#                       output (writes BENCH_hot_paths.json into the
#                       repo root; includes the serving-engine cases)
#   make bench-serving— just the serving-engine throughput cases
#                       (batched vs single-request dispatch at queue
#                       depths 1/8/64), written to BENCH_serving.json
#   make bench-gemm   — just the packed-GEMM cases (proxy-shape
#                       kernels, fused epilogue, serving throughput at
#                       queue depth 64), written to BENCH_gemm.json
#   make bench-report — run the benchmarks, then diff the fresh
#                       BENCH_hot_paths.json against the committed
#                       BENCH_baseline.json, printing per-path speedup
#                       ratios. The first toolchain run seeds the empty
#                       baseline and commits it (the trajectory anchor);
#                       later runs never touch the committed file.

.PHONY: verify bench bench-serving bench-gemm bench-report

# Clippy's pedantic style lints (arg-count, index-loop shape) conflict
# with the kernel code's explicit-index idiom; everything else is -D.
CLIPPY_LINTS = -D warnings \
	-A clippy::too_many_arguments \
	-A clippy::needless_range_loop \
	-A clippy::manual_div_ceil

verify:
	cargo build --release && cargo test -q
	cargo clippy --all-targets -- $(CLIPPY_LINTS)
	cargo test --release -q -p admm_nn --test integration_pipeline
	cargo run --release -p admm_nn --example quickstart

# Cargo runs bench binaries with CWD = the package root (rust/), so pin
# the JSON output to the repo root where bench-report expects it.
bench:
	BENCH_JSON_DIR=$(CURDIR) cargo bench --bench hot_paths -- --json

bench-serving:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=serving cargo bench --bench hot_paths -- --json

bench-gemm:
	BENCH_JSON_DIR=$(CURDIR) BENCH_ONLY=gemm cargo bench --bench hot_paths -- --json

bench-report: bench
	@cp BENCH_baseline.json .bench_baseline.before 2>/dev/null || true
	cargo run --release -p admm_nn --bin bench-report -- BENCH_hot_paths.json BENCH_baseline.json
	@# Auto-commit ONLY a genuine first seeding: the pre-run baseline was
	@# the empty placeholder ("results":[]) and the tool filled it in. A
	@# hand-edited or otherwise-diverged baseline is never touched, and a
	@# failed commit (e.g. no git identity) only prints a note.
	@if grep -q '"results":\[\]' .bench_baseline.before 2>/dev/null \
	   && ! cmp -s BENCH_baseline.json .bench_baseline.before; then \
		git add BENCH_baseline.json && \
		git commit -q -m "Seed benchmark baseline from first toolchain run" -- BENCH_baseline.json \
		&& echo "committed seeded BENCH_baseline.json" \
		|| echo "note: baseline seeded but not committed (commit it manually)"; \
	fi; rm -f .bench_baseline.before
