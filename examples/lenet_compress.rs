//! End-to-end headline experiment: LeNet-5 joint compression (Tables 1, 5).
//!
//! The full workload a user of the framework would run, proving every
//! layer composes (synthetic data → rust coordinator → AOT JAX/Pallas
//! artifacts via PJRT → compressed model container):
//!
//! 1. dense-train the *exact* Caffe LeNet-5 (430.5K params) on the
//!    synthetic digit dataset, logging the loss curve to CSV;
//! 2. joint ADMM prune (layer-wise α, paper-style CONV/FC asymmetry)
//!    + quantize (3b conv / 2b fc, Table 5's widths);
//! 3. run the paper's baselines at the same target for comparison:
//!    iterative magnitude pruning (Han), one-shot projection, and
//!    L1-regularization pruning (Wen-style);
//! 4. print Table-1/5-style rows and write MeasuredRun JSON so
//!    `admm-nn report --table 1/5` picks the numbers up.
//!
//! Runtime budget: ~15-25 min CPU. Override with --fast for a smoke run.
//!
//! Run: `cargo run --release --example lenet_compress [-- --fast]`
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use std::time::Instant;

use admm_nn::backend::{native::NativeBackend, ModelExec};
use admm_nn::baselines;
use admm_nn::coordinator::{pipeline, AdmmConfig, PipelineConfig, TrainConfig, Trainer};
use admm_nn::data;
use admm_nn::report::MeasuredRun;
use admm_nn::runtime::{Runtime, TrainState};
use admm_nn::util::{fmt_bytes, fmt_ratio};

fn main() -> admm_nn::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    // (pretrain, admm iters, steps/iter, retrain, baseline rounds)
    let (pre, iters, spi, retrain, rounds) =
        if fast { (200, 2, 60, 100, 2) } else { (900, 5, 150, 400, 4) };

    let rt;
    let pjrt_sess;
    let native_sess;
    let sess: &dyn ModelExec =
        if std::path::Path::new("artifacts/manifest.json").exists() {
            rt = Runtime::load("artifacts")?;
            pjrt_sess = rt.model("lenet5")?;
            &pjrt_sess
        } else {
            println!("(artifacts not built -- running on the native backend)");
            native_sess = NativeBackend::open("lenet5")?;
            &native_sess
        };
    let ds = data::for_input_shape(&sess.entry().input_shape);
    std::fs::create_dir_all("results")?;

    // Layer-wise keep ratios in the paper's 85×-run shape: conv1 stays
    // denser (input-adjacent), fc1 is pruned hardest.
    let keep = vec![0.55, 0.08, 0.012, 0.12];
    let target_ratio = {
        let total: f64 = sess.entry().weight_params().map(|p| p.numel() as f64).sum();
        let kept: f64 = sess
            .entry()
            .weight_params()
            .zip(&keep)
            .map(|(p, &a)| p.numel() as f64 * a)
            .sum();
        total / kept
    };
    println!(
        "LeNet-5 joint compression — target {} pruning, 3b conv / 2b fc",
        fmt_ratio(target_ratio)
    );

    // -- 1. dense pretraining ----------------------------------------------
    let t0 = Instant::now();
    let mut st = TrainState::init(sess.entry(), 0);
    let mut trainer = Trainer::new(sess, ds.as_ref());
    let log = trainer.run(&mut st, &TrainConfig {
        steps: pre,
        eval_every: (pre / 6).max(1),
        eval_batches: 8,
        verbose: true,
        ..Default::default()
    })?;
    std::fs::write("results/lenet_dense_loss.csv", log.to_csv())?;
    let dense_acc = sess.evaluate(&st, ds.as_ref(), 16)?.accuracy();
    println!("dense accuracy {:.4} ({:.0}s)", dense_acc, t0.elapsed().as_secs_f64());
    let dense_state = st.clone();

    // -- 2. ADMM joint pipeline ---------------------------------------------
    let t_admm = Instant::now();
    let cfg = PipelineConfig {
        prune_keep: keep.clone(),
        quant_bits: Some(vec![3, 3, 2, 2]),
        admm: AdmmConfig { iters, steps_per_iter: spi, verbose: true, ..Default::default() },
        retrain_steps: retrain,
        verbose: true,
        ..Default::default()
    };
    let rep = pipeline::run_pipeline(sess, ds.as_ref(), &mut st, &cfg)?;
    let admm_wall = t_admm.elapsed().as_secs_f64();
    let size = rep.model.size_report(sess.entry().total_weight_count() as u64);
    rep.model.save("results/lenet5_admm.admm")?;

    // -- 3. baselines at the same layer-wise target --------------------------
    println!("\n== baselines (same per-layer keep targets) ==");
    let t_b = Instant::now();
    let mut bst = dense_state.clone();
    let han = baselines::iterative_magnitude(
        sess, ds.as_ref(), &mut bst, &keep, rounds, retrain / rounds as u64,
        1e-3, 8,
    )?;
    let han_wall = t_b.elapsed().as_secs_f64();
    println!("  {:<28} acc {:.4}  prune {}", han.name, han.accuracy,
             fmt_ratio(han.overall_prune_ratio));

    let mut bst = dense_state.clone();
    let oneshot = baselines::one_shot_prune(
        sess, ds.as_ref(), &mut bst, &keep, retrain, 1e-3, 8)?;
    println!("  {:<28} acc {:.4}  prune {}", oneshot.name, oneshot.accuracy,
             fmt_ratio(oneshot.overall_prune_ratio));

    let mut bst = dense_state.clone();
    let l1 = baselines::l1_then_prune(
        sess, ds.as_ref(), &mut bst, 5e-5, iters as u64 * spi, &keep,
        retrain, 1e-3, 8)?;
    println!("  {:<28} acc {:.4}  prune {}", l1.name, l1.accuracy,
             fmt_ratio(l1.overall_prune_ratio));

    // -- 4. report ------------------------------------------------------------
    println!("\n== LeNet-5 results (synthetic digits) ==");
    println!("{:<30} {:>9} {:>11}", "method", "accuracy", "prune ratio");
    println!("{:<30} {:>9.4} {:>11}", "dense", dense_acc, "1x");
    println!("{:<30} {:>9.4} {:>11}", "ADMM-NN joint (ours)", rep.final_acc,
             fmt_ratio(rep.overall_prune_ratio));
    println!("{:<30} {:>9.4} {:>11}", han.name, han.accuracy,
             fmt_ratio(han.overall_prune_ratio));
    println!("{:<30} {:>9.4} {:>11}", oneshot.name, oneshot.accuracy,
             fmt_ratio(oneshot.overall_prune_ratio));
    println!("{:<30} {:>9.4} {:>11}", l1.name, l1.accuracy,
             fmt_ratio(l1.overall_prune_ratio));
    println!(
        "\nmodel size: dense {} -> data {} ({}) -> with indices {} ({})",
        fmt_bytes(size.dense_bytes()),
        fmt_bytes(size.data_bytes()),
        fmt_ratio(size.data_compress_ratio()),
        fmt_bytes(size.model_bytes()),
        fmt_ratio(size.model_compress_ratio())
    );
    println!(
        "wall: ADMM pipeline {:.0}s vs iterative baseline {:.0}s",
        admm_wall, han_wall
    );

    // Persist for `admm-nn report` + EXPERIMENTS.md.
    for (method, acc, ratio, lk, bits) in [
        ("admm joint", rep.final_acc, rep.overall_prune_ratio,
         rep.layer_keep.clone(), rep.quant.iter().map(|q| q.bits).collect::<Vec<_>>()),
        ("iterative magnitude", han.accuracy, han.overall_prune_ratio,
         han.layer_keep.clone(), vec![32; 4]),
        ("one-shot prune", oneshot.accuracy, oneshot.overall_prune_ratio,
         oneshot.layer_keep.clone(), vec![32; 4]),
        ("l1 regularization", l1.accuracy, l1.overall_prune_ratio,
         l1.layer_keep.clone(), vec![32; 4]),
    ] {
        MeasuredRun {
            model: "lenet5".into(),
            method: method.into(),
            dense_accuracy: dense_acc,
            accuracy: acc,
            prune_ratio: ratio,
            layer_keep: lk,
            bits,
            data_bytes: size.data_bytes(),
            model_bytes: size.model_bytes(),
            wall_s: admm_wall,
        }
        .save(std::path::Path::new("results"))?;
    }
    println!("\nresults written to results/ (see `admm-nn report --table 1`)");
    Ok(())
}
