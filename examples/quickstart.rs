//! Quickstart: compress a small MLP with the ADMM-NN joint pipeline.
//!
//! Demonstrates the whole public API in ~2 minutes on a laptop CPU:
//! 1. load the AOT artifacts (`make artifacts` first),
//! 2. dense-train an MLP on the synthetic digit dataset,
//! 3. run the joint ADMM prune (10×) + quantize pipeline,
//! 4. print the accuracy / size summary and save the compressed model.
//!
//! Run: `cargo run --release --example quickstart`

use admm_nn::coordinator::{pipeline, AdmmConfig, PipelineConfig, TrainConfig, Trainer};
use admm_nn::data;
use admm_nn::runtime::{Runtime, TrainState};
use admm_nn::util::fmt_bytes;

fn main() -> admm_nn::Result<()> {
    let rt = Runtime::load("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let sess = rt.model("mlp")?;
    let ds = data::for_input_shape(&sess.entry.input_shape);

    // 1. dense pretraining
    println!("== dense pretraining ==");
    let mut st = TrainState::init(&sess.entry, 0);
    let mut trainer = Trainer::new(&sess, ds.as_ref());
    trainer.run(&mut st, &TrainConfig { steps: 300, verbose: true, ..Default::default() })?;
    let dense = sess.evaluate(&st, ds.as_ref(), 8)?;
    println!("dense accuracy: {:.4}", dense.accuracy());

    // 2. joint ADMM compression: 10x pruning, auto bit selection
    println!("\n== joint ADMM prune (10x) + quantize ==");
    let n_w = sess.entry.n_weights();
    let cfg = PipelineConfig {
        prune_keep: vec![0.1; n_w],
        admm: AdmmConfig { iters: 3, steps_per_iter: 80, verbose: true, ..Default::default() },
        retrain_steps: 150,
        verbose: true,
        ..Default::default()
    };
    let rep = pipeline::run_pipeline(&sess, ds.as_ref(), &mut st, &cfg)?;

    // 3. summary
    println!("\n== summary ==");
    println!("{:<12} {:>9} {:>9} {:>7} {:>6}", "layer", "total", "kept", "keep%", "bits");
    for ((name, total, kept), q) in rep.layer_keep.iter().zip(&rep.quant) {
        println!(
            "{:<12} {:>9} {:>9} {:>6.1}% {:>6}",
            name, total, kept,
            *kept as f64 / *total as f64 * 100.0,
            q.bits
        );
    }
    let size = rep.model.size_report(sess.entry.total_weight_count() as u64);
    println!(
        "\naccuracy: dense {:.4} -> pruned {:.4} -> stored {:.4}",
        rep.dense_acc, rep.pruned_acc, rep.final_acc
    );
    println!(
        "size: dense {} -> data {} ({:.0}x) -> with indices {} ({:.0}x)",
        fmt_bytes(size.dense_bytes()),
        fmt_bytes(size.data_bytes()),
        size.data_compress_ratio(),
        fmt_bytes(size.model_bytes()),
        size.model_compress_ratio()
    );

    // 4. persist + reload round trip
    std::fs::create_dir_all("results")?;
    rep.model.save("results/quickstart_mlp.admm")?;
    let loaded = admm_nn::coordinator::CompressedModel::load("results/quickstart_mlp.admm")?;
    println!(
        "saved + reloaded compressed model: {} layers, stored accuracy {:.4}",
        loaded.layers.len(),
        loaded.accuracy
    );
    Ok(())
}
