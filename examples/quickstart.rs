//! Quickstart: compress a small MLP with the ADMM-NN joint pipeline.
//!
//! Runs entirely on the **native** execution backend — pure-Rust host
//! training and inference, no PJRT plugin and no AOT artifacts needed —
//! so this works on a fresh checkout:
//! 1. dense-train an MLP on the synthetic digit dataset,
//! 2. run the joint ADMM prune (10×) + quantize pipeline,
//! 3. print the accuracy / size summary and save the compressed model,
//! 4. reload it, register it in a `serving::ServingEngine`, and serve
//!    inference requests *from the stored representation* (RelIndex →
//!    CSR sparse execution behind the engine's micro-batching
//!    scheduler), cross-checking the logits against dense masked
//!    inference.
//!
//! Run: `cargo run --release --example quickstart`
//! (swap `NativeBackend::open` for `Runtime::load("artifacts")` +
//! `rt.model("mlp")` to drive the same pipeline through PJRT.)
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::backend::native::NativeBackend;
use admm_nn::backend::sparse_infer::SparseInfer;
use admm_nn::backend::{ModelExec, TrainState};
use admm_nn::coordinator::{pipeline, AdmmConfig, PipelineConfig, TrainConfig, Trainer};
use admm_nn::data::{self, Dataset};
use admm_nn::serving::{EngineConfig, InferRequest, ModelRegistry, ServingEngine};
use admm_nn::util::{fmt_bytes, ThreadPool};

fn main() -> admm_nn::Result<()> {
    let sess = NativeBackend::open("mlp")?;
    println!(
        "backend: native (host-side, {} pool lanes)",
        ThreadPool::global().threads()
    );
    let ds = data::for_input_shape(&sess.entry().input_shape);

    // 1. dense pretraining
    println!("== dense pretraining ==");
    let mut st = TrainState::init(sess.entry(), 0);
    let mut trainer = Trainer::new(&sess, ds.as_ref());
    trainer.run(&mut st, &TrainConfig { steps: 300, verbose: true, ..Default::default() })?;
    let dense = sess.evaluate(&st, ds.as_ref(), 8)?;
    println!("dense accuracy: {:.4}", dense.accuracy());

    // 2. joint ADMM compression: 10x pruning, auto bit selection
    println!("\n== joint ADMM prune (10x) + quantize ==");
    let n_w = sess.entry().n_weights();
    let cfg = PipelineConfig {
        prune_keep: vec![0.1; n_w],
        admm: AdmmConfig { iters: 3, steps_per_iter: 80, verbose: true, ..Default::default() },
        retrain_steps: 150,
        verbose: true,
        ..Default::default()
    };
    let rep = pipeline::run_pipeline(&sess, ds.as_ref(), &mut st, &cfg)?;

    // 3. summary
    println!("\n== summary ==");
    println!("{:<12} {:>9} {:>9} {:>7} {:>6}", "layer", "total", "kept", "keep%", "bits");
    for ((name, total, kept), q) in rep.layer_keep.iter().zip(&rep.quant) {
        println!(
            "{:<12} {:>9} {:>9} {:>6.1}% {:>6}",
            name, total, kept,
            *kept as f64 / *total as f64 * 100.0,
            q.bits
        );
    }
    let size = rep.model.size_report(sess.entry().total_weight_count() as u64);
    println!(
        "\naccuracy: dense {:.4} -> pruned {:.4} -> stored {:.4}",
        rep.dense_acc, rep.pruned_acc, rep.final_acc
    );
    println!(
        "size: dense {} -> data {} ({:.0}x) -> with indices {} ({:.0}x)",
        fmt_bytes(size.dense_bytes()),
        fmt_bytes(size.data_bytes()),
        size.data_compress_ratio(),
        fmt_bytes(size.model_bytes()),
        size.model_compress_ratio()
    );

    // 4. persist + reload round trip, then serve from the stored form
    std::fs::create_dir_all("results")?;
    rep.model.save("results/quickstart_mlp.admm")?;
    let loaded = admm_nn::coordinator::CompressedModel::load("results/quickstart_mlp.admm")?;
    println!(
        "saved + reloaded compressed model: {} layers, stored accuracy {:.4}",
        loaded.layers.len(),
        loaded.accuracy
    );

    // The serving engine owns the decoded model (shared immutable CSR
    // behind an Arc); requests go through submit/poll or infer_sync and
    // are micro-batched — with per-request logits bit-identical to a
    // direct single-request call.
    let server = SparseInfer::new(&loaded, sess.entry())?;
    let nnz = server.nnz();
    let direct = {
        // direct single-model path, kept for the bitwise cross-check
        let batch = ds.batch(data::Split::Test, 0, 64);
        server.infer_with(ThreadPool::global(), &batch.x, 64)?
    };
    let mut registry = ModelRegistry::new();
    registry.register_named("mlp".into(), std::sync::Arc::new(server))?;
    let engine = ServingEngine::new(registry, EngineConfig::default())?;

    let batch = ds.batch(data::Split::Test, 0, 64);
    let dim: usize = sess.entry().input_shape.iter().product();
    // 64 independent single-example requests, coalesced by the engine
    let tickets: Vec<_> = (0..64)
        .map(|i| {
            engine.submit(InferRequest::new(
                "mlp",
                batch.x[i * dim..(i + 1) * dim].to_vec(),
            ))
        })
        .collect::<Result<_, _>>()?;
    let mut sparse_logits = Vec::with_capacity(64 * 10);
    for t in tickets {
        sparse_logits.extend(engine.wait(t)?);
    }
    assert_eq!(
        sparse_logits, direct,
        "engine batching drifted from the direct sparse call"
    );

    let restored = loaded.restore_params(sess.entry())?;
    let mut vst = st.clone();
    vst.params = restored;
    let dense_logits = sess.infer(&vst, &batch.x, 64)?;
    let mut max_err = 0.0f32;
    for (i, (a, b)) in sparse_logits.iter().zip(&dense_logits).enumerate() {
        let d = (a - b).abs();
        // explicit per-logit gate: a NaN diff must fail, not fall out
        // of a max() fold
        assert!(
            d <= 1e-4,
            "sparse serving drifted from dense inference at logit {i}: \
             {a} vs {b}"
        );
        max_err = max_err.max(d);
    }
    let stats = engine.stats("mlp").expect("mlp is registered");
    println!(
        "sparse serving ({nnz} stored nonzeros): max |sparse - dense| \
         logit error {max_err:.2e} over 64 requests"
    );
    println!("engine: {}", stats.summary());
    Ok(())
}
