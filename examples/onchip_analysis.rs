//! On-chip feasibility + hardware analysis (paper §4.3, Fig. 4, Table 9).
//!
//! Pure-analysis example: no training, no artifacts needed. Regenerates
//! the storage/compute arithmetic over the exact network descriptors and
//! the calibrated hardware model, including an ablation sweep over the
//! model's constants (index width, SRAM split) showing how the break-even
//! ratio moves.
//!
//! Run: `cargo run --release --example onchip_analysis`
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::hwmodel::HwConfig;
use admm_nn::models;
use admm_nn::report;
use admm_nn::sparsity::{best_index_bits, LayerSize, SizeReport};
use admm_nn::util::{fmt_bytes, fmt_ratio};

fn main() {
    // §4.3 feasibility table
    println!("{}", report::onchip());

    // Fig. 4 sweep + break-even
    let hw = HwConfig::default();
    println!("{}", report::fig4(&hw));

    // Table 9
    println!("{}", report::table9(&hw));

    // Ablation: how the break-even ratio depends on the co-design knobs.
    println!("Break-even sensitivity (ablation over hardware constants)");
    println!("{}", "-".repeat(64));
    println!("{:<44} {:>8} {:>10}", "configuration", "portion", "ratio");
    let mut configs: Vec<(String, HwConfig)> =
        vec![("default (calibrated to paper)".into(), hw)];
    for bits in [2u32, 4, 8] {
        let cfg = HwConfig { index_bits: bits, ..hw };
        configs.push((format!("index bits = {bits}"), cfg));
    }
    for frac in [0.55, 0.65, 0.85] {
        let cfg = HwConfig { weight_sram_frac: frac, ..hw };
        configs.push((format!("weight SRAM fraction = {frac}"), cfg));
    }
    for pen in [0.0, 0.2] {
        let cfg = HwConfig { freq_penalty: pen, ..hw };
        configs.push((format!("sparse clock penalty = {pen}"), cfg));
    }
    for (name, cfg) in configs {
        println!(
            "{:<44} {:>7.1}% {:>10}",
            name,
            cfg.break_even_portion() * 100.0,
            fmt_ratio(cfg.break_even_ratio())
        );
    }

    // Storage deep-dive: what makes AlexNet fit on-chip (paper: 2.45MB).
    println!("\nAlexNet on-chip storage budget (ADMM-NN profile)");
    println!("{}", "-".repeat(72));
    let net = models::alexnet();
    let profile = models::profiles::alexnet_ours_table7();
    let bits = [5u32, 5, 5, 5, 5, 3, 3, 3]; // Table 6 widths
    println!(
        "{:<8} {:>10} {:>7} {:>6} {:>7} {:>12} {:>12}",
        "layer", "kept", "keep%", "wbits", "ibits", "data", "with index"
    );
    let mut layers = Vec::new();
    for ((l, &a), &b) in net.layers.iter().zip(&profile.keep).zip(&bits) {
        let ib = best_index_bits(a, b);
        let ls = LayerSize::estimate(l.weights, a, b, ib);
        println!(
            "{:<8} {:>10} {:>6.1}% {:>6} {:>7} {:>12} {:>12}",
            l.name,
            ls.kept_weights,
            a * 100.0,
            b,
            ib,
            fmt_bytes(ls.data_bits() as f64 / 8.0),
            fmt_bytes(ls.model_bits() as f64 / 8.0)
        );
        layers.push(ls);
    }
    let report = SizeReport { dense_params: net.total_params(), layers };
    println!(
        "total: data {} ({}), with indices {} ({}) — vs dense {}",
        fmt_bytes(report.data_bytes()),
        fmt_ratio(report.data_compress_ratio()),
        fmt_bytes(report.model_bytes()),
        fmt_ratio(report.model_compress_ratio()),
        fmt_bytes(report.dense_bytes())
    );
}
