//! Hardware-aware compression of the AlexNet proxy (paper §5, Table 9).
//!
//! Runs the Fig. 5 algorithm live: compute-proportional α reduction under
//! an accuracy constraint (binary-searched with real short ADMM probes),
//! break-even restoration against the calibrated hardware model, and the
//! synthesized per-layer / overall speedup report.
//!
//! Run: `cargo run --release --example hw_aware_alexnet [-- --fast]`
// Crate-root style allowances, matching rust/src/lib.rs (these used to
// be -A flags on the Makefile's clippy invocation).
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::manual_div_ceil)]

use admm_nn::backend::{native::NativeBackend, ModelExec};
use admm_nn::coordinator::hw_aware::{hw_aware_compress, HwAwareConfig};
use admm_nn::coordinator::{AdmmConfig, TrainConfig, Trainer};
use admm_nn::data;
use admm_nn::hwmodel::HwConfig;
use admm_nn::report::MeasuredRun;
use admm_nn::runtime::{Runtime, TrainState};
use admm_nn::util::fmt_ratio;

fn main() -> admm_nn::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (pre, iters, spi, retrain, probes) =
        if fast { (150, 2, 40, 60, 2) } else { (500, 3, 80, 150, 4) };

    let rt;
    let pjrt_sess;
    let native_sess;
    let sess: &dyn ModelExec =
        if std::path::Path::new("artifacts/manifest.json").exists() {
            rt = Runtime::load("artifacts")?;
            pjrt_sess = rt.model("alexnet_proxy")?;
            &pjrt_sess
        } else {
            println!("(artifacts not built -- running on the native backend)");
            native_sess = NativeBackend::open("alexnet_proxy")?;
            &native_sess
        };
    let ds = data::for_input_shape(&sess.entry().input_shape);
    let hw = HwConfig::default();
    println!(
        "hardware model: break-even portion {:.1}% -> ratio {}",
        hw.break_even_portion() * 100.0,
        fmt_ratio(hw.break_even_ratio())
    );

    // dense pretraining
    println!("== dense pretraining ({pre} steps) ==");
    let mut st = TrainState::init(sess.entry(), 0);
    let mut trainer = Trainer::new(sess, ds.as_ref());
    trainer.run(&mut st, &TrainConfig {
        steps: pre,
        verbose: true,
        ..Default::default()
    })?;

    // hardware-aware compression (Fig. 5)
    println!("\n== hardware-aware compression ==");
    let cfg = HwAwareConfig {
        hw,
        acc_drop_tol: 0.02,
        admm: AdmmConfig { iters, steps_per_iter: spi, ..Default::default() },
        retrain_steps: retrain,
        search_probes: probes,
        eval_batches: 4,
        verbose: true,
        ..Default::default()
    };
    let res = hw_aware_compress(sess, ds.as_ref(), &st, &cfg)?;

    // Table-9-style report on the proxy
    println!("\n== synthesized speedups (proxy conv layers) ==");
    println!("{:<10} {:>8} {:>10} {:>10}", "layer", "keep", "ratio", "speedup");
    for (name, alpha, speedup) in &res.speedup.layers {
        println!(
            "{:<10} {:>7.1}% {:>10} {:>9.2}x{}",
            name,
            alpha * 100.0,
            fmt_ratio(1.0 / alpha),
            speedup,
            if *alpha == 1.0 { "   <- restored (below break-even)" } else { "" }
        );
    }
    println!("overall conv speedup: {:.2}x", res.speedup.overall);
    println!(
        "accuracy: dense {:.4} -> compressed {:.4} (tolerance {:.3})",
        res.dense_accuracy, res.accuracy, cfg.acc_drop_tol
    );
    println!("probes evaluated: {}", res.probes.len());
    for (s, acc, ok) in &res.probes {
        println!("  s={s:.3} acc={acc:.4} {}", if *ok { "accept" } else { "reject" });
    }

    // persist
    std::fs::create_dir_all("results")?;
    let wps: Vec<_> = sess.entry().weight_params().collect();
    MeasuredRun {
        model: "alexnet_proxy".into(),
        method: "hw-aware admm".into(),
        dense_accuracy: res.dense_accuracy,
        accuracy: res.accuracy,
        prune_ratio: {
            let total: f64 = wps.iter().map(|p| p.numel() as f64).sum();
            let kept: f64 = wps.iter().zip(&res.keep)
                .map(|(p, &a)| p.numel() as f64 * a).sum();
            total / kept
        },
        layer_keep: wps
            .iter()
            .zip(&res.keep)
            .map(|(p, &a)| {
                (p.name.clone(), p.numel(),
                 (p.numel() as f64 * a).round() as usize)
            })
            .collect(),
        bits: vec![32; wps.len()],
        data_bytes: 0.0,
        model_bytes: 0.0,
        wall_s: 0.0,
    }
    .save(std::path::Path::new("results"))?;
    println!("\nresults written to results/");
    Ok(())
}
